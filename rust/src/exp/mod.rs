//! Experiment harness: one driver per paper table/figure (DESIGN.md §6).
//!
//! Every driver prints the paper-shaped table/series to stdout and writes
//! CSVs under `runs/`. Workloads are scaled to minutes-on-CPU (see
//! DESIGN.md §3 for the substitution argument); pass `--full` for the
//! larger configurations recorded in EXPERIMENTS.md.

pub mod common;
pub mod fig1;
pub mod fig3_loss;
pub mod fig4_variance;
pub mod fig5_no_train;
pub mod fig6_levels;
pub mod fig7_sweep;
pub mod fig8_convergence;
pub mod table1;
pub mod table2;
pub mod timing;

use anyhow::{bail, Result};

/// All experiment ids, mapped to the paper artifact they regenerate.
pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("fig1", "Fig. 1 — variance of normalized coordinates during training"),
    ("table1", "Table 1 — validation accuracy, 4 workers, 3 bits"),
    ("table2", "Table 2 — scaling to 16/32 workers"),
    ("table4", "Table 4 — long-horizon headline (table1 --long)"),
    ("fig3", "Fig. 3 — validation loss curves"),
    ("fig4", "Fig. 4 — gradient variance during training"),
    ("fig5", "Fig. 5 — variance on the frozen SGD trajectory"),
    ("fig6", "Fig. 6 — final quantization levels per method + per-step bit-width trajectories"),
    ("fig7", "Fig. 7 — bucket-size and bit-width sweeps"),
    ("fig8", "Fig. 8 — convergence of level-update methods"),
    ("fig14", "Fig. 14 (K.2) — gradient clipping ablation (fig7 --clip)"),
    ("timing", "Tables 5–7 — per-step and level-update timing"),
];

/// Dispatch an experiment by id.
pub fn run(name: &str, args: &[String]) -> Result<()> {
    match name {
        "fig1" => fig1::run(args),
        "table1" => table1::run(args),
        "table2" => table2::run(args),
        "table4" => {
            let mut a = args.to_vec();
            a.push("--long".into());
            table1::run(&a)
        }
        "fig3" => fig3_loss::run(args),
        "fig4" => fig4_variance::run(args),
        "fig5" => fig5_no_train::run(args),
        "fig6" => fig6_levels::run(args),
        "fig7" => fig7_sweep::run(args),
        "fig8" => fig8_convergence::run(args),
        "fig14" => {
            let mut a = args.to_vec();
            a.push("--clip".into());
            fig7_sweep::run(&a)
        }
        "timing" => timing::run(args),
        other => bail!(
            "unknown experiment {other:?}; available: {:?}",
            EXPERIMENTS.iter().map(|(n, _)| *n).collect::<Vec<_>>()
        ),
    }
}
