//! Shared helpers for integration tests.

use std::net::TcpListener;

/// Bind a listener on a kernel-assigned free port and return it with
/// its dialable address. Every TCP test goes through this instead of
/// hardcoding ports, so parallel test binaries never collide.
pub fn free_listener() -> (TcpListener, String) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind 127.0.0.1:0");
    let addr = listener.local_addr().expect("local_addr").to_string();
    (listener, addr)
}
