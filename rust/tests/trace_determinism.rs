//! Trace-determinism contract (DESIGN.md §Observability): a traced run's
//! masked event sequence is bit-identical across `--parallel on|off`,
//! sim and TCP runs agree on the (event, step, width) projection for
//! `fixed:3`, every event type the tracers emit validates against the
//! schema registry (and the registry has no dead entries), and the
//! `trace-summarize` fold reconstructs per-step bits exactly.

mod common;

use aqsgd::coordinator::leader::run_leader_topo_traced;
use aqsgd::coordinator::{run_worker_traced, WorkerConfig};
use aqsgd::data::Blobs;
use aqsgd::exchange::{BitsPolicy, ParallelMode, TopologySpec};
use aqsgd::model::{Mlp, MlpTask};
use aqsgd::opt::{LrSchedule, UpdateSchedule};
use aqsgd::quant::{Codec, Method, QuantizeImpl};
use aqsgd::sim::{Cluster, ClusterConfig, FaultPlan, NetworkModel, TrainRecord};
use aqsgd::trace::summary::{masked_lines, validate_event, TraceSummary, EVENT_TYPES};
use aqsgd::trace::{Level, Tracer};
use aqsgd::util::json::Json;
use std::collections::BTreeSet;

const ITERS: usize = 24;
const WORLD: usize = 4;

fn sim_cfg(topology: TopologySpec, parallel: ParallelMode) -> ClusterConfig {
    ClusterConfig {
        method: Method::Alq,
        workers: WORLD,
        bits: BitsPolicy::Fixed(3),
        bucket: 64,
        iters: ITERS,
        lr: LrSchedule::paper_default(0.1, ITERS),
        updates: UpdateSchedule::at(vec![3, 20], 50, 20),
        momentum: 0.9,
        weight_decay: 1e-4,
        seed: 42,
        eval_every: 0,
        variance_every: 0,
        network: NetworkModel::paper_testbed(),
        parallel,
        topology,
        codec: Codec::Huffman,
        quantize_impl: QuantizeImpl::default(),
        pipeline: aqsgd::exchange::PipelineMode::Off,
        faults: FaultPlan::default(),
        error_feedback: false,
        lazy: aqsgd::exchange::LazyPolicy::Off,
    }
}

fn sim_task() -> MlpTask {
    let blobs = Blobs::generate(8, 4, 1600, 400, 1.0, 7);
    MlpTask::new(Mlp::new(vec![8, 32, 4]), blobs, 32, WORLD, 7)
}

/// One traced sim training: the raw JSONL the tracer wrote + the record.
fn sim_trace(
    topology: TopologySpec,
    parallel: ParallelMode,
    level: Level,
) -> (String, TrainRecord) {
    let mut cluster = Cluster::new(sim_cfg(topology, parallel));
    let (tracer, buf) = Tracer::memory(level);
    cluster.set_tracer(tracer);
    let rec = cluster.train(&mut sim_task());
    let text = buf.lock().unwrap().clone();
    (text, rec)
}

/// One traced TCP run (flat, fixed:3, same horizon as the sim): worker
/// 0's JSONL and the leader's JSONL.
fn tcp_trace(level: Level) -> (String, String) {
    let (listener, addr) = common::free_listener();
    let (leader_tracer, leader_buf) = Tracer::memory(level);
    let leader = std::thread::spawn(move || {
        run_leader_topo_traced(listener, WORLD, ITERS, TopologySpec::Flat, &leader_tracer).unwrap()
    });
    let (w0_tracer, w0_buf) = Tracer::memory(level);
    let mut handles = Vec::new();
    for w in 0..WORLD {
        let addr = addr.clone();
        // Only worker 0 traces: the projection contract is per-replica.
        let tracer = if w == 0 { w0_tracer.clone() } else { Tracer::disabled() };
        handles.push(std::thread::spawn(move || {
            let cfg = WorkerConfig {
                addr,
                worker: w,
                world: WORLD,
                method: Method::Alq,
                bits: BitsPolicy::Fixed(3),
                bucket: 64,
                iters: ITERS,
                lr: LrSchedule::paper_default(0.1, ITERS),
                updates: UpdateSchedule::at(vec![3, 20], 50, 20),
                momentum: 0.9,
                weight_decay: 1e-4,
                seed: 42,
                topology: TopologySpec::Flat,
                codec: Codec::Huffman,
                quantize_impl: QuantizeImpl::default(),
                pipeline: aqsgd::exchange::PipelineMode::Off,
                faults: FaultPlan::default(),
                error_feedback: false,
                lazy: aqsgd::exchange::LazyPolicy::Off,
            };
            run_worker_traced(&cfg, &mut sim_task(), &tracer).unwrap()
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    leader.join().unwrap();
    let w0 = w0_buf.lock().unwrap().clone();
    let lead = leader_buf.lock().unwrap().clone();
    (w0, lead)
}

/// The deterministic projection sim and TCP runs must share: the
/// (event, step, width) sequence of `bit_decision` and `step` events.
fn width_projection(text: &str) -> Vec<(String, usize, u32)> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .filter_map(|l| {
            let ev = Json::parse(l).unwrap();
            let e = ev.get("e").and_then(|v| v.as_str()).unwrap().to_string();
            if e != "bit_decision" && e != "step" {
                return None;
            }
            let num = |k: &str| ev.get(k).and_then(|v| v.as_f64()).unwrap();
            Some((e, num("step") as usize, num("width") as u32))
        })
        .collect()
}

/// The tentpole determinism contract: with wall-clock fields masked, the
/// event sequence is byte-identical across `--parallel on|off` — span
/// presence is structural and emission happens on the calling thread in
/// schedule order, so threading must not reorder or reshape the trace.
#[test]
fn masked_event_sequence_identical_across_parallel_modes() {
    for topology in [TopologySpec::Flat, TopologySpec::Tree(2)] {
        let (on, _) = sim_trace(topology, ParallelMode::Parallel, Level::Debug);
        let (off, _) = sim_trace(topology, ParallelMode::Serial, Level::Debug);
        let on = masked_lines(&on).unwrap();
        let off = masked_lines(&off).unwrap();
        assert!(!on.is_empty());
        assert_eq!(
            on,
            off,
            "masked trace diverges across --parallel on|off over {}",
            topology.name()
        );
    }
}

/// `trace-summarize` must reconstruct per-step totals exactly: every
/// `step` event's bits equals the sim's `StepStats.bits`, Σ hop bits
/// matches every step, and the fold's total equals `comm_bits`.
#[test]
fn summary_reconstructs_per_step_bits_exactly() {
    let (text, rec) = sim_trace(TopologySpec::Flat, ParallelMode::Auto, Level::Debug);
    let s = TraceSummary::from_jsonl(&text).unwrap();
    assert!(s.hop_bits_mismatches.is_empty(), "{:?}", s.hop_bits_mismatches);
    assert_eq!(s.steps.len(), rec.steps.len());
    for (row, stat) in s.steps.iter().zip(&rec.steps) {
        assert_eq!(row.step, stat.step);
        assert_eq!(row.bits, stat.bits, "step {} bits diverge", stat.step);
        assert_eq!(row.width, stat.width);
    }
    let total: u64 = s.steps.iter().map(|r| r.bits).sum();
    assert_eq!(total, rec.comm_bits);
    // The sim traced hops for every step and attributed codec phases.
    assert!(s.by_type["hop"] >= ITERS);
    assert!(s.phase_totals.contains_key("quantize"));
    assert!(s.phase_totals.contains_key("wire"));
}

/// Sim and TCP runtimes share the width-decision protocol
/// (`budget::select_width`) and the step roll-up, so for `fixed:3` a
/// worker's (event, step, width) projection matches the sim's exactly.
/// (Bits are excluded: a sim step meters all workers, a TCP worker only
/// its own frames; quantization RNG streams also differ by design.)
#[test]
fn sim_and_tcp_flat_agree_on_width_and_step_projection() {
    let (sim_text, _) = sim_trace(TopologySpec::Flat, ParallelMode::Auto, Level::Info);
    let (worker_text, _) = tcp_trace(Level::Info);
    let sim_proj = width_projection(&sim_text);
    let tcp_proj = width_projection(&worker_text);
    assert_eq!(sim_proj.len(), 2 * ITERS, "one bit_decision + one step per step");
    assert_eq!(
        sim_proj, tcp_proj,
        "sim and TCP flat disagree on the (event, step, width) sequence for fixed:3"
    );
}

/// Every line of real sim, worker, and leader traces validates against
/// the schema registry — and together they exercise every registered
/// event type, so the registry carries no dead entries. A faulted sim
/// run covers the membership events (`member_drop`, `member_join`); a
/// synthetic deadline miss covers `timeout` (the leader only emits it
/// under real wall-clock stalls, which this test must not depend on).
#[test]
fn every_event_type_appears_and_validates() {
    let (sim_text, _) = sim_trace(TopologySpec::Flat, ParallelMode::Auto, Level::Debug);
    let (worker_text, leader_text) = tcp_trace(Level::Debug);
    let (warn_tracer, warn_buf) = Tracer::memory(Level::Warn);
    warn_tracer.warn_event("test", "synthetic degradation notice");
    let warn_text = warn_buf.lock().unwrap().clone();

    // Churn coverage: kill one worker and activate a standby mid-run.
    let mut faulted_cfg = sim_cfg(TopologySpec::Flat, ParallelMode::Auto);
    faulted_cfg.faults = FaultPlan::parse("kill:1@3,join:2@8").unwrap();
    let mut cluster = Cluster::new(faulted_cfg);
    let (fault_tracer, fault_buf) = Tracer::memory(Level::Info);
    cluster.set_tracer(fault_tracer);
    cluster.train(&mut sim_task());
    let fault_text = fault_buf.lock().unwrap().clone();
    for kind in ["member_drop", "member_join"] {
        assert!(
            fault_text.contains(&format!("\"e\":\"{kind}\"")),
            "faulted sim run emitted no {kind} event"
        );
    }

    // Skip-round coverage: a feedback + lazy run where every message
    // fails the send gate emits `skip` (Info) and `feedback_norm`
    // (Debug) every step.
    let mut lazy_cfg = sim_cfg(TopologySpec::Flat, ParallelMode::Auto);
    lazy_cfg.error_feedback = true;
    lazy_cfg.lazy = aqsgd::exchange::LazyPolicy::Thresh(1e30);
    let mut lazy_cluster = Cluster::new(lazy_cfg);
    let (lazy_tracer, lazy_buf) = Tracer::memory(Level::Debug);
    lazy_cluster.set_tracer(lazy_tracer);
    lazy_cluster.train(&mut sim_task());
    let lazy_text = lazy_buf.lock().unwrap().clone();
    for kind in ["skip", "feedback_norm"] {
        assert!(
            lazy_text.contains(&format!("\"e\":\"{kind}\"")),
            "lazy sim run emitted no {kind} event"
        );
    }

    // Timeout coverage: the exact event shape the leader's
    // timeout-and-drop path emits on a deadline miss.
    let (timeout_tracer, timeout_buf) = Tracer::memory(Level::Info);
    timeout_tracer.event(Level::Info, "timeout", |o| {
        o.insert("step", Json::Num(3.0));
        o.insert("worker", Json::Num(1.0));
        o.insert("attempt", Json::Num(0.0));
        o.insert("deadline_ms", Json::Num(50.0));
    });
    let timeout_text = timeout_buf.lock().unwrap().clone();

    let mut seen = BTreeSet::new();
    for text in [
        &sim_text,
        &worker_text,
        &leader_text,
        &warn_text,
        &fault_text,
        &lazy_text,
        &timeout_text,
    ] {
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let ev = Json::parse(line).unwrap();
            validate_event(&ev).unwrap_or_else(|e| panic!("{e}"));
            seen.insert(ev.get("e").and_then(|v| v.as_str()).unwrap().to_string());
        }
    }
    let expected: BTreeSet<String> = EVENT_TYPES.iter().map(|s| s.kind.to_string()).collect();
    assert_eq!(seen, expected, "trace coverage drifted from the schema registry");
}
