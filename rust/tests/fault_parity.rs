//! Fault parity: the deterministic fault-injection contract
//! (DESIGN.md §Membership). One `FaultPlan` drives both runtimes, and
//! they must agree step-for-step on the elastic-membership projection:
//!
//! * **Full parity (fp32):** for `super-sgd` the sim and the TCP
//!   cluster agree on (step, active-set, width, bits, params_hash) —
//!   aggregation order and the `1/n_active` weighting are op-identical,
//!   so replica hashes match bit-for-bit every step, under a kill and
//!   under a kill+join plan, over flat and tree topologies.
//! * **Projection parity (quantized):** for ALQ the two runtimes use
//!   different RNG derivations by design, but (step, active-set,
//!   width) still match, and all TCP survivors stay bit-identical.
//! * **Inertness:** an empty plan changes nothing — the elastic leader
//!   with its default deadlines reproduces the pre-elastic blocking
//!   leader (`deadline_ms: 0`) exactly.
//! * **Timeout-and-drop:** a real straggler (injected `delay`) misses
//!   its per-frame deadline, is dropped after bounded retries, and the
//!   survivors' run equals the sim run with that worker killed at the
//!   same step; a short delay inside the retry budget survives.
//!
//! Tree bits are pinned analytically rather than cross-checked: the
//! sim meters the down-broadcast (up + 2·lead per present group) while
//! the leader meters received frames only (up + lead) — both must
//! equal their closed forms `32·d·(n_active + 2·present)` and
//! `32·d·(n_active + present)`.

mod common;

use aqsgd::coordinator::{
    run_leader_elastic, run_worker, ElasticPolicy, LeaderReport, WorkerConfig, WorkerReport,
};
use aqsgd::data::Blobs;
use aqsgd::exchange::{BitsPolicy, LazyPolicy, ParallelMode, TopologySpec, SKIP_MARKER_BITS};
use aqsgd::model::{Mlp, MlpTask};
use aqsgd::opt::{LrSchedule, UpdateSchedule};
use aqsgd::quant::{Codec, Method, QuantizeImpl};
use aqsgd::sim::{Cluster, ClusterConfig, FaultPlan, NetworkModel, TrainRecord};
use aqsgd::trace::{Level, Tracer};

const WORLD: usize = 4;
const ITERS: usize = 12;

/// The two seeded plans every parity test runs under: a plain kill and
/// a kill plus a late join (worker 2 starts as a standby replica).
const PLANS: [&str; 2] = ["kill:1@3", "kill:1@3,join:2@8"];

fn task() -> MlpTask {
    let blobs = Blobs::generate(8, 4, 1600, 400, 1.0, 7);
    MlpTask::new(Mlp::new(vec![8, 32, 4]), blobs, 32, WORLD, 7)
}

fn dims() -> u64 {
    Mlp::new(vec![8, 32, 4]).param_count() as u64
}

fn sim_run(method: Method, topology: TopologySpec, faults: &str, iters: usize) -> TrainRecord {
    sim_run_lazy(method, topology, faults, iters, LazyPolicy::Off)
}

fn sim_run_lazy(
    method: Method,
    topology: TopologySpec,
    faults: &str,
    iters: usize,
    lazy: LazyPolicy,
) -> TrainRecord {
    let cfg = ClusterConfig {
        method,
        workers: WORLD,
        bits: BitsPolicy::Fixed(3),
        bucket: 128,
        iters,
        lr: LrSchedule::paper_default(0.1, iters),
        updates: UpdateSchedule::at(vec![3, 20], 50, 20),
        momentum: 0.9,
        weight_decay: 1e-4,
        seed: 42,
        eval_every: 0,
        variance_every: 0,
        network: NetworkModel::paper_testbed(),
        parallel: ParallelMode::Auto,
        topology,
        codec: Codec::Huffman,
        quantize_impl: QuantizeImpl::default(),
        pipeline: aqsgd::exchange::PipelineMode::Off,
        faults: FaultPlan::parse(faults).unwrap(),
        error_feedback: false,
        lazy,
    };
    Cluster::new(cfg).train(&mut task())
}

struct TcpRun {
    leader: LeaderReport,
    leader_trace: String,
    /// One slot per worker; a dropped worker's thread errors out when
    /// the leader closes its socket, which parity tests ignore.
    workers: Vec<Result<WorkerReport, String>>,
}

fn tcp_run(
    method: Method,
    topology: TopologySpec,
    faults: &str,
    iters: usize,
    policy: ElasticPolicy,
) -> TcpRun {
    tcp_run_lazy(method, topology, faults, iters, policy, LazyPolicy::Off)
}

fn tcp_run_lazy(
    method: Method,
    topology: TopologySpec,
    faults: &str,
    iters: usize,
    policy: ElasticPolicy,
    lazy: LazyPolicy,
) -> TcpRun {
    let (listener, addr) = common::free_listener();
    let (tracer, buf) = Tracer::memory(Level::Info);
    let leader = std::thread::spawn(move || {
        run_leader_elastic(listener, WORLD, iters, topology, policy, &tracer).unwrap()
    });
    let plan = FaultPlan::parse(faults).unwrap();
    let mut handles = Vec::new();
    for w in 0..WORLD {
        let addr = addr.clone();
        let plan = plan.clone();
        handles.push(std::thread::spawn(move || {
            let cfg = WorkerConfig {
                addr,
                worker: w,
                world: WORLD,
                method,
                bits: BitsPolicy::Fixed(3),
                bucket: 128,
                iters,
                lr: LrSchedule::paper_default(0.1, iters),
                updates: UpdateSchedule::at(vec![3, 20], 50, 20),
                momentum: 0.9,
                weight_decay: 1e-4,
                seed: 42,
                topology,
                codec: Codec::Huffman,
                quantize_impl: QuantizeImpl::default(),
                pipeline: aqsgd::exchange::PipelineMode::Off,
                faults: plan,
                error_feedback: false,
                lazy,
            };
            run_worker(&cfg, &mut task()).map_err(|e| e.to_string())
        }));
    }
    let workers = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let leader = leader.join().unwrap();
    let leader_trace = buf.lock().unwrap().clone();
    TcpRun {
        leader,
        leader_trace,
        workers,
    }
}

/// Groups for `tree:2` over 4 workers are {0,1} and {2,3}; a group is
/// present when any of its members is active.
fn tree_present(mask: u64) -> u64 {
    u64::from(mask & 0b0011 != 0) + u64::from(mask & 0b1100 != 0)
}

/// Tentpole: full (step, active-set, width, bits, params_hash) parity
/// for fp32 under both seeded plans over flat and tree.
#[test]
fn fp32_sim_tcp_full_parity_under_churn() {
    let d = dims();
    for faults in PLANS {
        for topology in [TopologySpec::Flat, TopologySpec::Tree(2)] {
            let ctx = format!("{faults} over {}", topology.name());
            let sim = sim_run(Method::SuperSgd, topology, faults, ITERS);
            let tcp = tcp_run(
                Method::SuperSgd,
                topology,
                faults,
                ITERS,
                ElasticPolicy::default(),
            );
            let w0 = tcp.workers[0].as_ref().expect("worker 0 survives");
            assert_eq!(sim.steps.len(), ITERS, "{ctx}");
            assert_eq!(tcp.leader.steps.len(), ITERS, "{ctx}");
            assert_eq!(w0.step_records.len(), ITERS, "{ctx}");
            for s in 0..ITERS {
                let st = &sim.steps[s];
                let lr = &tcp.leader.steps[s];
                let wr = &w0.step_records[s];
                assert_eq!(st.step, s, "{ctx}");
                assert_eq!(wr.step as usize, s, "{ctx}");
                assert_eq!(st.active, wr.active_mask, "{ctx}: active diverges at step {s}");
                assert_eq!(st.active, lr.active_mask, "{ctx}: leader mask at step {s}");
                assert_eq!(st.width, 32, "{ctx}");
                assert_eq!(wr.width, 32, "{ctx}");
                assert_eq!(
                    st.params_hash, wr.params_hash,
                    "{ctx}: replica hash diverges at step {s}"
                );
                let n_active = u64::from(st.active.count_ones());
                match topology {
                    TopologySpec::Flat => {
                        assert_eq!(st.bits, 32 * d * n_active, "{ctx}: sim bits at step {s}");
                        assert_eq!(lr.bits, 32 * d * n_active, "{ctx}: leader bits at step {s}");
                    }
                    _ => {
                        let present = tree_present(st.active);
                        assert_eq!(
                            st.bits,
                            32 * d * (n_active + 2 * present),
                            "{ctx}: sim bits at step {s}"
                        );
                        assert_eq!(
                            lr.bits,
                            32 * d * (n_active + present),
                            "{ctx}: leader bits at step {s}"
                        );
                    }
                }
            }
            // The killed worker exits at the top of its kill step with
            // exactly the pre-kill prefix of the shared record stream.
            let w1 = tcp.workers[1].as_ref().expect("killed worker exits cleanly");
            assert_eq!(w1.step_records.len(), 3, "{ctx}");
            assert_eq!(w1.step_records[..], w0.step_records[..3], "{ctx}");
            // Survivors — including the standby joiner — stay replicas.
            for w in 2..WORLD {
                let wr = tcp.workers[w].as_ref().expect("survivor");
                assert_eq!(wr.step_records, w0.step_records, "{ctx}: worker {w}");
            }
            assert_eq!(sim.params_hash, w0.params_hash, "{ctx}: final hash");
        }
    }
}

/// Quantized runs derive their dither RNGs differently per runtime, so
/// only the membership projection is pinned: (step, active-set, width)
/// match, and TCP survivors stay bit-identical to each other.
#[test]
fn quantized_sim_tcp_agree_on_membership_projection() {
    for faults in PLANS {
        for topology in [TopologySpec::Flat, TopologySpec::Tree(2)] {
            let ctx = format!("{faults} over {}", topology.name());
            let sim = sim_run(Method::Alq, topology, faults, ITERS);
            let tcp = tcp_run(Method::Alq, topology, faults, ITERS, ElasticPolicy::default());
            let w0 = tcp.workers[0].as_ref().expect("worker 0 survives");
            for s in 0..ITERS {
                let st = &sim.steps[s];
                let wr = &w0.step_records[s];
                assert_eq!(st.active, wr.active_mask, "{ctx}: active at step {s}");
                assert_eq!(st.active, tcp.leader.steps[s].active_mask, "{ctx}: step {s}");
                assert_eq!(st.width, wr.width, "{ctx}: width at step {s}");
            }
            for w in 2..WORLD {
                let wr = tcp.workers[w].as_ref().expect("survivor");
                assert_eq!(wr.step_records, w0.step_records, "{ctx}: worker {w}");
            }
        }
    }
}

/// An empty fault plan is inert: the elastic leader (default deadlines)
/// and the pre-elastic blocking leader (`deadline_ms: 0`) produce
/// identical runs, both matching the sim, with a full mask throughout
/// and no membership events in the leader trace.
#[test]
fn empty_fault_plan_is_inert() {
    let sim = sim_run(Method::SuperSgd, TopologySpec::Flat, "none", ITERS);
    let elastic = tcp_run(
        Method::SuperSgd,
        TopologySpec::Flat,
        "none",
        ITERS,
        ElasticPolicy::default(),
    );
    let blocking = tcp_run(
        Method::SuperSgd,
        TopologySpec::Flat,
        "none",
        ITERS,
        ElasticPolicy {
            deadline_ms: 0,
            retries: 0,
        },
    );
    for (name, run) in [("elastic", &elastic), ("blocking", &blocking)] {
        let w0 = run.workers[0].as_ref().expect("fault-free worker");
        for s in 0..ITERS {
            assert_eq!(run.leader.steps[s].active_mask, 0b1111, "{name}: step {s}");
            assert_eq!(w0.step_records[s].active_mask, 0b1111, "{name}: step {s}");
            assert_eq!(
                w0.step_records[s].params_hash, sim.steps[s].params_hash,
                "{name}: step {s}"
            );
        }
        for kind in ["member_drop", "member_join", "timeout"] {
            assert!(
                !run.leader_trace.contains(&format!("\"e\":\"{kind}\"")),
                "{name}: fault-free run emitted a {kind} event"
            );
        }
    }
    assert_eq!(elastic.leader.total_bits, blocking.leader.total_bits);
    for w in 0..WORLD {
        assert_eq!(
            elastic.workers[w].as_ref().unwrap().step_records,
            blocking.workers[w].as_ref().unwrap().step_records,
            "worker {w}: elastic vs blocking leader"
        );
    }
}

/// Timeout-and-drop: a worker stalling 2 s against a 50 ms deadline
/// (one retry) is dropped mid-run, the leader traces the timeout, the
/// drop, and a survivor weight sum of exactly 1 — and the survivors'
/// run equals the sim with that worker killed at the same step.
#[test]
fn deadline_miss_drops_straggler_and_survivors_renormalize() {
    let iters = 6;
    let sim = sim_run(Method::SuperSgd, TopologySpec::Flat, "kill:1@2", iters);
    let tcp = tcp_run(
        Method::SuperSgd,
        TopologySpec::Flat,
        "delay:1@2:2000",
        iters,
        ElasticPolicy {
            deadline_ms: 50,
            retries: 1,
        },
    );
    assert!(
        tcp.leader_trace.matches("\"e\":\"timeout\"").count() >= 1,
        "no timeout event in leader trace"
    );
    assert_eq!(
        tcp.leader_trace.matches("\"e\":\"member_drop\"").count(),
        1,
        "expected exactly one drop"
    );
    assert!(
        tcp.leader_trace.contains("\"weight_sum\":1"),
        "drop event must certify survivor weights sum to 1"
    );
    // Worker 1's socket is closed under it mid-run; its error (or
    // truncated report) is not part of the contract.
    for w in [0, 2, 3] {
        let wr = tcp.workers[w].as_ref().expect("survivor");
        assert_eq!(wr.step_records.len(), iters, "worker {w}");
        for s in 0..iters {
            assert_eq!(
                wr.step_records[s].active_mask, sim.steps[s].active,
                "worker {w}: active at step {s}"
            );
            assert_eq!(
                wr.step_records[s].params_hash, sim.steps[s].params_hash,
                "worker {w}: replica hash at step {s}"
            );
        }
    }
}

/// Lazy-aggregation parity: skip decisions are pure functions of the
/// gradients, so one `--lazy` spec produces the same skip plan on both
/// runtimes. For fp32 the full (step, sent-mask, width, bits,
/// params_hash) projection matches — including genuinely zero-frame
/// steps, which meter exactly `n·SKIP_MARKER_BITS` on both sides —
/// under an unreachable threshold (every step skips) and a
/// patience-bounded LAQ gate (a frame every 4th step), over flat and
/// tree relays.
#[test]
fn lazy_skip_plans_agree_between_sim_and_tcp() {
    let d = dims();
    // laq:1e12@3: step 0 sends (no reference yet); the huge gain keeps
    // every later drift under threshold, so frames recur exactly when
    // the K=3 patience runs out — sends at steps 0, 4, 8, …, a
    // data-independent plan mixing zero-frame and full steps.
    for (name, lazy, send_period) in [
        ("thresh:1e30", LazyPolicy::Thresh(1e30), None),
        ("laq:1e12@3", LazyPolicy::parse("laq:1e12@3").unwrap(), Some(4usize)),
    ] {
        for topology in [TopologySpec::Flat, TopologySpec::Tree(2)] {
            let ctx = format!("{name} over {}", topology.name());
            let sim = sim_run_lazy(Method::SuperSgd, topology, "none", ITERS, lazy);
            let tcp = tcp_run_lazy(
                Method::SuperSgd,
                topology,
                "none",
                ITERS,
                ElasticPolicy::default(),
                lazy,
            );
            let w0 = tcp.workers[0].as_ref().expect("worker 0");
            assert!(sim.skipped_frames > 0, "{ctx}: no zero-frame worker-steps");
            for s in 0..ITERS {
                let st = &sim.steps[s];
                let wr = &w0.step_records[s];
                let lr = &tcp.leader.steps[s];
                let send = send_period.is_some_and(|p| s % p == 0);
                let want_sent: u64 = if send { 0b1111 } else { 0 };
                assert_eq!(st.sent, want_sent, "{ctx}: sim sent-mask at step {s}");
                assert_eq!(wr.sent_mask, want_sent, "{ctx}: tcp sent-mask at step {s}");
                assert_eq!(st.active, 0b1111, "{ctx}: skippers must stay active");
                assert_eq!(wr.active_mask, 0b1111, "{ctx}");
                assert_eq!(st.width, 32, "{ctx}");
                assert_eq!(wr.width, 32, "{ctx}");
                assert_eq!(
                    st.params_hash, wr.params_hash,
                    "{ctx}: replica hash diverges at step {s}"
                );
                let (sim_bits, leader_bits) = if send {
                    match topology {
                        TopologySpec::Flat => (32 * d * 4, 32 * d * 4),
                        _ => (32 * d * (4 + 2 * 2), 32 * d * (4 + 2)),
                    }
                } else {
                    (4 * SKIP_MARKER_BITS, 4 * SKIP_MARKER_BITS)
                };
                assert_eq!(st.bits, sim_bits, "{ctx}: sim bits at step {s}");
                assert_eq!(lr.bits, leader_bits, "{ctx}: leader bits at step {s}");
            }
            for w in 1..WORLD {
                let wr = tcp.workers[w].as_ref().expect("worker");
                assert_eq!(wr.step_records, w0.step_records, "{ctx}: worker {w}");
            }
        }
    }
    // Quantized runs agree on the same mask/width projection (bits and
    // hashes differ by design: the runtimes build their codebooks on
    // different lifecycles, like the other quantized parity tests).
    let lazy = LazyPolicy::parse("laq:1e12@3").unwrap();
    let sim = sim_run_lazy(Method::Alq, TopologySpec::Flat, "none", ITERS, lazy);
    let tcp = tcp_run_lazy(
        Method::Alq,
        TopologySpec::Flat,
        "none",
        ITERS,
        ElasticPolicy::default(),
        lazy,
    );
    let w0 = tcp.workers[0].as_ref().expect("worker 0");
    for s in 0..ITERS {
        assert_eq!(sim.steps[s].sent, w0.step_records[s].sent_mask, "alq step {s}");
        assert_eq!(sim.steps[s].width, w0.step_records[s].width, "alq step {s}");
    }
}

/// A transient stall inside the retry budget is absorbed: the first
/// attempt times out, a doubled-deadline retry succeeds, nobody is
/// dropped, and all four workers finish bit-identical with full masks.
#[test]
fn transient_delay_survives_within_retry_budget() {
    let iters = 6;
    let tcp = tcp_run(
        Method::SuperSgd,
        TopologySpec::Flat,
        "delay:1@2:500",
        iters,
        ElasticPolicy {
            deadline_ms: 200,
            retries: 3,
        },
    );
    assert!(
        tcp.leader_trace.matches("\"e\":\"timeout\"").count() >= 1,
        "the 500 ms stall must miss the 200 ms first deadline"
    );
    assert_eq!(
        tcp.leader_trace.matches("\"e\":\"member_drop\"").count(),
        0,
        "retry budget covers the stall; nobody should be dropped"
    );
    let w0 = tcp.workers[0].as_ref().expect("worker 0");
    assert_eq!(w0.step_records.len(), iters);
    for w in 0..WORLD {
        let wr = tcp.workers[w].as_ref().expect("no worker should fail");
        assert_eq!(wr.step_records, w0.step_records, "worker {w}");
        assert!(
            wr.step_records.iter().all(|r| r.active_mask == 0b1111),
            "worker {w}: mask must stay full"
        );
    }
}
