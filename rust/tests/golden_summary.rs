//! Golden-file test for `trace-summarize --json`: the machine-readable
//! summary document (`aqsgd-trace-summary/v1`) is byte-stable. A fixed
//! fixture trace — one line per interesting event kind, including the
//! elastic-membership events — is summarized by the real binary, and
//! the output must match `rust/tests/golden/trace_summary.json` byte
//! for byte.
//!
//! Regenerate after an intentional schema change with
//! `UPDATE_GOLDEN=1 cargo test --test golden_summary`; the golden is
//! also bootstrapped on first run if missing (then committed, so CI
//! diffs catch any later drift).
//!
//! All `seconds` values in the fixture are dyadic rationals, so their
//! sums are exact in f64 and the JSON rendering is portable.

use std::path::PathBuf;
use std::process::Command;

/// The fixture: a deterministic JSONL trace with every summary-relevant
/// event kind. Hop bits sum to each step's total (`trace-summarize`
/// hard-fails otherwise), the churn events mirror what the elastic
/// leader emits on a deadline miss and a scheduled join, and step 1
/// carries a `--lazy` skip round (a 104-bit marker hop folded into the
/// step total) plus an `--error-feedback` residual-norm sample.
const FIXTURE: &str = r#"{"e":"run_start","seq":0,"runtime":"sim"}
{"e":"connect","seq":1,"worker":0,"world":4}
{"e":"bit_decision","seq":2,"step":0,"width":3}
{"e":"phase","seq":3,"step":0,"phase":"quantize","seconds":0.5}
{"e":"phase","seq":4,"step":0,"phase":"wire","wall_seconds":0.25}
{"e":"hop","seq":5,"step":0,"index":0,"label":"up","bits":960,"seconds":0.125}
{"e":"hop","seq":6,"step":0,"index":1,"label":"down","bits":320,"seconds":0.125}
{"e":"frame_send","seq":7,"step":0,"kind":"grad","bytes":120,"width":3}
{"e":"frame_recv","seq":8,"step":0,"kind":"all_grads","frames":4,"bytes":480}
{"e":"relay","seq":9,"step":0,"frames":4,"bits":960}
{"e":"step","seq":10,"step":0,"bits":1280,"width":3}
{"e":"adapt","seq":11,"step":0,"updated":true}
{"e":"timeout","seq":12,"step":1,"worker":1,"attempt":0,"deadline_ms":50}
{"e":"member_drop","seq":13,"step":1,"worker":1,"active":3,"weight_sum":1}
{"e":"warning","seq":14,"component":"leader","message":"worker 1 dropped at step 1 (deadline); 3 active"}
{"e":"member_join","seq":15,"step":2,"worker":2,"active":4,"weight_sum":1}
{"e":"feedback_norm","seq":16,"step":1,"worker":2,"norm":0.5}
{"e":"skip","seq":17,"step":1,"worker":2,"bits":104,"weight_sum":1}
{"e":"hop","seq":18,"step":1,"index":0,"label":"up","bits":720,"seconds":0.0625}
{"e":"hop","seq":19,"step":1,"index":1,"label":"skip","bits":104,"seconds":0.03125}
{"e":"step","seq":20,"step":1,"bits":824,"width":4}
{"e":"run_end","seq":21,"steps":2,"total_bits":2104}
"#;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden")
        .join("trace_summary.json")
}

#[test]
fn trace_summarize_json_matches_golden() {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let fixture = dir.join(format!("aqsgd_golden_fixture_{pid}.jsonl"));
    let out = dir.join(format!("aqsgd_golden_out_{pid}.json"));
    std::fs::write(&fixture, FIXTURE).unwrap();

    let status = Command::new(env!("CARGO_BIN_EXE_aqsgd"))
        .arg("trace-summarize")
        .arg(&fixture)
        .arg("--json")
        .arg(&out)
        .status()
        .expect("running the aqsgd binary");
    assert!(status.success(), "trace-summarize failed on the fixture");
    let produced = std::fs::read_to_string(&out).unwrap();
    std::fs::remove_file(&fixture).ok();
    std::fs::remove_file(&out).ok();

    // The CLI and the library must agree before the golden is consulted.
    let folded = aqsgd::trace::summary::TraceSummary::from_jsonl(FIXTURE).unwrap();
    assert_eq!(
        produced,
        format!("{}\n", folded.to_json()),
        "CLI output diverges from TraceSummary::to_json"
    );
    assert!(produced.contains("\"schema\":\"aqsgd-trace-summary/v1\""));
    assert!(
        produced.contains("\"skips\":{\"frames\":1,\"marker_bits\":104}"),
        "skip rounds missing from the summary: {produced}"
    );
    assert!(
        produced.contains("\"feedback\":{\"max_norm\":0.5,\"samples\":1}"),
        "feedback section missing from the summary: {produced}"
    );

    let golden = golden_path();
    if std::env::var_os("UPDATE_GOLDEN").is_some() || !golden.exists() {
        std::fs::create_dir_all(golden.parent().unwrap()).unwrap();
        std::fs::write(&golden, &produced).unwrap();
        eprintln!("golden regenerated: {}", golden.display());
        return;
    }
    let expected = std::fs::read_to_string(&golden).unwrap();
    assert_eq!(
        produced, expected,
        "summary JSON drifted from {} — if intentional, regenerate with UPDATE_GOLDEN=1",
        golden.display()
    );
}
