//! Cross-module integration tests: artifacts → runtime → quantizer →
//! cluster → coordinator, plus executable-theory checks at system level.

use aqsgd::adaptive::{update_levels, Estimator};
use aqsgd::model::{HloMlpTask, TrainTask};
use aqsgd::opt::{LrSchedule, UpdateSchedule};
use aqsgd::quant::{self, theory, Levels, Method, NormType, Quantizer};
use aqsgd::runtime::{Manifest, Runtime};
use aqsgd::sim::{Cluster, ClusterConfig, NetworkModel};
use aqsgd::util::Rng;

fn artifacts_ready() -> bool {
    Manifest::default_dir().join("manifest.json").exists()
}

/// Full stack over the HLO model: quantized data-parallel training on the
/// PJRT-executed MLP must learn, meter bits, and adapt levels.
#[test]
fn quantized_training_over_hlo_model() {
    if !artifacts_ready() {
        aqsgd::trace::warn("artifacts", "skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load_default().unwrap();
    let workers = 2;
    let mut task = HloMlpTask::load(&rt, &manifest, "mlp_tiny", workers, 5).unwrap();
    let d = task.param_count();
    let iters = 120;
    let cfg = ClusterConfig {
        method: Method::Alq,
        workers,
        bits: aqsgd::exchange::BitsPolicy::Fixed(3),
        bucket: 64,
        iters,
        lr: LrSchedule::paper_default(0.1, iters),
        updates: UpdateSchedule::at(vec![2, 20], 50, 20),
        momentum: 0.9,
        weight_decay: 1e-4,
        seed: 1,
        eval_every: 0,
        variance_every: 0,
        network: NetworkModel::paper_testbed(),
        parallel: aqsgd::exchange::ParallelMode::Auto,
        topology: aqsgd::exchange::TopologySpec::Flat,
        codec: aqsgd::quant::Codec::Huffman,
        quantize_impl: aqsgd::quant::QuantizeImpl::default(),
        pipeline: aqsgd::exchange::PipelineMode::Off,
        faults: aqsgd::sim::FaultPlan::default(),
        error_feedback: false,
        lazy: aqsgd::exchange::LazyPolicy::Off,
    };
    let rec = Cluster::new(cfg).train(&mut task);
    let first = rec.steps.first().unwrap().train_loss;
    let last: f64 = rec.steps.iter().rev().take(10).map(|s| s.train_loss).sum::<f64>() / 10.0;
    assert!(last < first * 0.8, "HLO training did not learn: {first} -> {last}");
    assert!(rec.level_updates >= 2);
    assert!(rec.comm_bits > 0 && rec.comm_bits < iters as u64 * workers as u64 * 32 * d as u64 / 3);
    let levels = rec.final_levels.unwrap();
    assert_ne!(levels, Method::Alq.initial_levels(3).unwrap().mags().to_vec());
}

/// The adaptive loop strictly reduces the Eq. (10) objective on the
/// fitted mixture for every adaptive method (system-level Theorem 1 use).
#[test]
fn adaptation_reduces_objective_on_real_gradients() {
    let spec = aqsgd::exp::common::ModelSpec::resnet8_standin();
    let mut task = spec.task(2, 3);
    let params = task.init_params(1);
    let mut grad = vec![0.0f32; task.param_count()];
    task.grad(&params, 0, 0, &mut grad);

    for method in [Method::Alq, Method::AlqN, Method::AlqG, Method::Amq] {
        let mut est = Estimator::new(spec.bucket, method.norm_type(), 20);
        est.observe(&grad);
        let mut rng = Rng::new(4);
        let mix = est.fit(method.weighted_mixture(), &mut rng).unwrap();
        let init = method.initial_levels(3).unwrap();
        let adapted = update_levels(method, &init, &mix);
        let before = aqsgd::adaptive::objective::psi(&mix, &init);
        let after = aqsgd::adaptive::objective::psi(&mix, &adapted);
        assert!(after <= before + 1e-12, "{method}: {before} -> {after}");
    }
}

/// Theorem 2/3 hold along a real training run (not just synthetic vectors).
#[test]
fn theory_bounds_hold_during_training() {
    let spec = aqsgd::exp::common::ModelSpec::resnet8_standin();
    let mut task = spec.task(1, 9);
    let params = task.init_params(2);
    let mut grad = vec![0.0f32; task.param_count()];
    task.grad(&params, 0, 0, &mut grad);

    for (method, qnorm) in [(Method::QsgdInf, 100.0), (Method::NuqSgd, 2.0), (Method::Alq, 100.0)] {
        let levels = method.initial_levels(3).unwrap();
        let quant = Quantizer::new(levels.clone(), method.norm_type(), grad.len());
        let eps = theory::epsilon_q(&levels, grad.len(), qnorm);
        let var = quant.exact_variance(&grad);
        let l2: f64 = grad.iter().map(|&x| (x as f64).powi(2)).sum();
        assert!(var <= eps * l2 + 1e-9, "{method}: {var} > {eps} * {l2}");
    }
}

/// Wire format survives a full quantize→encode→frame→decode round trip
/// (the exact path the TCP coordinator uses), including partial buckets.
#[test]
fn wire_roundtrip_preserves_gradients() {
    use aqsgd::coordinator::messages::{Msg, WireGrad};
    let levels = Levels::exponential(4, 0.5);
    let quant = Quantizer::new(levels.clone(), NormType::L2, 64);
    let mut rng = Rng::new(7);
    let v: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
    let g = quant.quantize(&v, &mut rng);
    let book = quant::HuffmanBook::from_weights(&[4.0, 3.0, 2.0, 1.0]);
    let enc = quant::encode(&g, &levels, &book);

    let msg = Msg::Grad { step: 3, grad: WireGrad::from_view(enc.view(), 3) };
    let mut buf = Vec::new();
    msg.write_to(&mut buf).unwrap();
    let got = Msg::read_from(&mut buf.as_slice()).unwrap();
    let Msg::Grad { grad, .. } = got else { panic!() };
    let dec = quant::decode(&grad.to_encoded(), &levels, &book);
    assert_eq!(dec, g);

    let mut out = vec![0.0f32; 1000];
    quant.dequantize(&dec, &mut out);
    assert_eq!(&out[960..], &v[960..], "fp32 tail must be exact");
}

/// The in-process simulation and the TCP coordinator implement the same
/// algorithm: same method/levels family, both learn, both meter bits of
/// the same order.
#[test]
fn cluster_and_coordinator_agree_qualitatively() {
    use aqsgd::coordinator::{leader::run_leader_on, run_worker, WorkerConfig};
    use aqsgd::data::Blobs;
    use aqsgd::model::{Mlp, MlpTask};
    use std::net::TcpListener;

    let iters = 150;
    let world = 2;
    // Simulated.
    let spec = aqsgd::exp::common::ModelSpec::resnet8_standin();
    let mut cfg = aqsgd::exp::common::cluster_config(Method::QsgdInf, &spec, iters, world, 3, 256, 11);
    cfg.eval_every = 0;
    let mut task = spec.task(world, 11);
    let sim = Cluster::new(cfg).train(&mut task);

    // Wire-true.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader = std::thread::spawn(move || run_leader_on(listener, world, iters).unwrap());
    let mut handles = Vec::new();
    for w in 0..world {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let cfg = WorkerConfig {
                addr,
                worker: w,
                world,
                method: Method::QsgdInf,
                bits: aqsgd::exchange::BitsPolicy::Fixed(3),
                bucket: 256,
                iters,
                lr: LrSchedule::paper_default(0.1, iters),
                updates: UpdateSchedule::paper_default(iters),
                momentum: 0.9,
                weight_decay: 1e-4,
                seed: 11,
                topology: aqsgd::exchange::TopologySpec::Flat,
                codec: aqsgd::quant::Codec::Huffman,
                quantize_impl: aqsgd::quant::QuantizeImpl::default(),
                pipeline: aqsgd::exchange::PipelineMode::Off,
                faults: aqsgd::sim::FaultPlan::default(),
                error_feedback: false,
                lazy: aqsgd::exchange::LazyPolicy::Off,
            };
            let blobs = Blobs::generate(32, 10, 16384, 1024, 0.8, 11);
            let mut task = MlpTask::new(Mlp::new(vec![32, 64, 10]), blobs, 16, world, 11);
            run_worker(&cfg, &mut task).unwrap()
        }));
    }
    let reports: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    leader.join().unwrap();

    assert!(sim.final_eval.accuracy > 0.5);
    assert!(reports[0].final_eval.accuracy > 0.5);
    // Bits per step within 2x of each other (different codebook refresh
    // cadence, same entropy regime).
    let sim_bits = sim.comm_bits as f64 / iters as f64 / world as f64;
    let wire_bits = reports[0].sent_bits as f64 / iters as f64;
    let ratio = sim_bits / wire_bits;
    assert!((0.5..2.0).contains(&ratio), "bits/step ratio {ratio}");
}

/// Huffman coding on a real gradient beats fixed-width coding and stays
/// within 1 bit/symbol of the empirical entropy (Theorem 5).
#[test]
fn entropy_coding_efficiency_on_real_gradients() {
    let spec = aqsgd::exp::common::ModelSpec::resnet8_standin();
    let mut task = spec.task(1, 13);
    let params = task.init_params(3);
    let mut grad = vec![0.0f32; task.param_count()];
    task.grad(&params, 0, 0, &mut grad);

    let levels = Levels::exponential(4, 0.5);
    let quant = Quantizer::new(levels.clone(), NormType::Linf, 256);
    let mut rng = Rng::new(14);
    let g = quant.quantize(&grad, &mut rng);
    let counts = quant::symbol_counts(&g, &levels);
    let total: f64 = counts.iter().sum();
    let probs: Vec<f64> = counts.iter().map(|c| c / total).collect();
    let book = quant::HuffmanBook::from_weights(&counts.iter().map(|c| c + 1.0).collect::<Vec<_>>());
    let h = theory::entropy_bits(&probs);
    let el = book.expected_length(&probs);
    assert!(el < h + 1.0, "E[L]={el} vs H={h}");
    // And beats 2-bit fixed coding whenever the distribution is skewed.
    if h < 1.8 {
        assert!(el < 2.0);
    }
}
