//! Exchange-engine parity: the unified `GradientExchange` must reproduce
//! the original serial codec loop bit for bit — same comm_bits, same
//! per-step bits, same adapted levels, same final parameters — and its
//! thread-parallel schedule must be indistinguishable from its serial
//! one. The reference below is the seed's in-process loop re-implemented
//! verbatim from public quant/adaptive/opt APIs: the oracle the engine is
//! checked against.
//!
//! Since the dynamic bit-budget refactor (ISSUE 4) this file is also the
//! pre-refactor pin for `--bits-policy fixed:B`: the oracle still
//! threads one constant width through the primitive quant APIs exactly
//! as the seed loop did, so `engine_matches_reference_serial_loop`
//! passing means the banked `CodecSession` + per-step `BitController`
//! machinery is provably inert at a fixed width.

use aqsgd::adaptive::{update_levels, Estimator};
use aqsgd::exchange::ParallelMode;
use aqsgd::data::Blobs;
use aqsgd::model::{Mlp, MlpTask, TrainTask};
use aqsgd::opt::{Optimizer, Sgd, Umsgd, UpdateSchedule};
use aqsgd::quant::{
    self, bitio::BitWriter, smooth_weights, EncodedView, HuffmanBook, Method, QuantizedGrad,
    Quantizer,
};
use aqsgd::sim::{Cluster, ClusterConfig};
use aqsgd::util::{hash_params, Rng};

struct RefOutcome {
    comm_bits: u64,
    step_bits: Vec<u64>,
    params_hash: u64,
    final_levels: Option<Vec<f64>>,
}

/// The seed serial training loop: quantize → encode → meter → decode →
/// aggregate per worker in order, lazy empirical codebook, sampled
/// symbol-count refresh every 10th step, adapt at the schedule 𝒰.
fn reference_train(cfg: &ClusterConfig, task: &mut dyn TrainTask) -> RefOutcome {
    let d = task.param_count();
    let mut seeder = Rng::new(cfg.seed);
    let mut rngs: Vec<Rng> = (0..cfg.workers).map(|w| seeder.fork(w as u64)).collect();
    let mut params = task.init_params(cfg.seed ^ 0xA5A5);
    let mut optimizer: Box<dyn Optimizer> = if cfg.momentum > 0.0 {
        Box::new(Umsgd::heavy_ball(cfg.momentum, cfg.weight_decay))
    } else {
        Box::new(Sgd::new(cfg.weight_decay))
    };
    // The oracle runs at the policy's (constant) width — reference
    // parity is only claimed for fixed:B configurations.
    assert!(cfg.bits.is_fixed(), "the reference oracle is fixed-width");
    let mut quantizer = cfg.method.initial_levels(cfg.bits.initial_bits()).map(|levels| {
        let mut q = Quantizer::new(levels, cfg.method.norm_type(), cfg.bucket);
        if let Some(c) = cfg.method.clip_factor() {
            q = q.with_clip(c);
        }
        q
    });
    let mut estimator = quantizer
        .as_ref()
        .map(|q| Estimator::new(cfg.bucket, q.norm_type(), 20));
    let mut sym_counts = quantizer
        .as_ref()
        .map(|q| vec![0.0; q.levels().num_symbols()])
        .unwrap_or_default();
    let mut book: Option<HuffmanBook> = None;

    let active = if cfg.method == Method::SingleSgd {
        1
    } else {
        cfg.workers
    };
    let mut grads = vec![vec![0.0f32; d]; active];
    let mut agg = vec![0.0f32; d];
    let mut ghat = vec![0.0f32; d];
    let empty = || QuantizedGrad {
        qidx: Vec::new(),
        norms: Vec::new(),
        tail: Vec::new(),
        bucket: cfg.bucket,
    };
    let mut qbuf = empty();
    let mut dec = empty();
    let mut writer = BitWriter::new();
    let mut comm_bits = 0u64;
    let mut step_bits_log = Vec::new();

    for step in 0..cfg.iters {
        for (w, g) in grads.iter_mut().enumerate() {
            task.grad(&params, w, step, g);
        }

        if quantizer.is_some() && cfg.updates.is_update_step(step) {
            let q = quantizer.as_mut().unwrap();
            let est = estimator.as_mut().unwrap();
            est.clear();
            for g in &grads {
                est.observe(g);
            }
            let mut rng = rngs[0].fork(0xE57);
            let mut adapted = false;
            if cfg.method.is_adaptive() {
                if let Some(mix) = est.fit(cfg.method.weighted_mixture(), &mut rng) {
                    let new_levels = update_levels(cfg.method, q.levels(), &mix);
                    q.set_levels(new_levels);
                    let probs = aqsgd::adaptive::objective::symbol_probs(&mix, q.levels());
                    book = Some(HuffmanBook::from_weights(&smooth_weights(&probs)));
                    sym_counts = vec![0.0; q.levels().num_symbols()];
                    adapted = true;
                }
            }
            if !adapted && sym_counts.iter().sum::<f64>() > 0.0 {
                book = Some(HuffmanBook::from_weights(&smooth_weights(&sym_counts)));
                for c in sym_counts.iter_mut() {
                    *c = 0.0;
                }
            }
        }

        agg.fill(0.0);
        let mut step_bits = 0u64;
        if let Some(q) = &quantizer {
            let inv = 1.0 / active as f32;
            for w in 0..active {
                q.quantize_into(&grads[w], &mut rngs[w], &mut qbuf);
                if book.is_none() {
                    let counts = quant::symbol_counts(&qbuf, q.levels());
                    book = Some(HuffmanBook::from_weights(&smooth_weights(&counts)));
                }
                if step % 10 == 0 {
                    for (c, n) in sym_counts
                        .iter_mut()
                        .zip(quant::symbol_counts(&qbuf, q.levels()))
                    {
                        *c += n;
                    }
                }
                let bk = book.as_ref().unwrap();
                writer.clear();
                let bits = quant::encode_into(&qbuf, q.levels(), bk, &mut writer);
                writer.finish_ref();
                let view = EncodedView {
                    bytes: writer.bytes(),
                    bits,
                    n_full: qbuf.qidx.len(),
                    n_tail: qbuf.tail.len(),
                    bucket: qbuf.bucket,
                };
                step_bits += bits;
                quant::decode_view_into(view, q.levels(), bk, &mut dec);
                q.dequantize(&dec, &mut ghat);
                for (a, &g) in agg.iter_mut().zip(&ghat) {
                    *a += g * inv;
                }
            }
        } else {
            for g in &grads {
                step_bits += 32 * d as u64;
                for (a, &x) in agg.iter_mut().zip(g) {
                    *a += x / active as f32;
                }
            }
        }
        comm_bits += step_bits;
        step_bits_log.push(step_bits);
        optimizer.step(&mut params, &agg, cfg.lr.lr(step));
    }

    RefOutcome {
        comm_bits,
        step_bits: step_bits_log,
        params_hash: hash_params(&params),
        final_levels: quantizer.as_ref().map(|q| q.levels().mags().to_vec()),
    }
}

fn task(workers: usize, seed: u64) -> MlpTask {
    let blobs = Blobs::generate(8, 4, 1600, 400, 1.0, seed);
    MlpTask::new(Mlp::new(vec![8, 32, 4]), blobs, 32, workers, seed)
}

fn config(method: Method, iters: usize, parallel: ParallelMode) -> ClusterConfig {
    let mut cfg = ClusterConfig::paper_default(method, iters);
    cfg.bucket = 128;
    cfg.eval_every = 0;
    cfg.seed = 5;
    cfg.updates = UpdateSchedule::at(vec![3, 15], 30, 15);
    cfg.parallel = parallel;
    cfg
}

#[test]
fn engine_matches_reference_serial_loop() {
    for method in [
        Method::Alq,
        Method::Amq,
        Method::QsgdInf,
        Method::NuqSgd,
        Method::SuperSgd,
        Method::SingleSgd,
    ] {
        let cfg = config(method, 40, ParallelMode::Serial);
        let want = reference_train(&cfg, &mut task(4, 3));
        let rec = Cluster::new(cfg).train(&mut task(4, 3));
        assert_eq!(rec.comm_bits, want.comm_bits, "{method}: comm_bits");
        assert_eq!(
            rec.steps.iter().map(|s| s.bits).collect::<Vec<_>>(),
            want.step_bits,
            "{method}: per-step bits"
        );
        assert_eq!(rec.final_levels, want.final_levels, "{method}: levels");
        assert_eq!(rec.params_hash, want.params_hash, "{method}: final params");
    }
}

#[test]
fn parallel_lanes_are_bit_identical_to_serial() {
    for method in [Method::Alq, Method::NuqSgd, Method::Trn] {
        let a = Cluster::new(config(method, 40, ParallelMode::Serial)).train(&mut task(4, 3));
        let b = Cluster::new(config(method, 40, ParallelMode::Parallel)).train(&mut task(4, 3));
        assert_eq!(a.comm_bits, b.comm_bits, "{method}: comm_bits");
        assert_eq!(
            a.steps.iter().map(|s| s.bits).collect::<Vec<_>>(),
            b.steps.iter().map(|s| s.bits).collect::<Vec<_>>(),
            "{method}: per-step bits"
        );
        assert_eq!(a.final_levels, b.final_levels, "{method}: levels");
        assert_eq!(a.params_hash, b.params_hash, "{method}: final params");
        assert_eq!(
            a.final_eval.loss.to_bits(),
            b.final_eval.loss.to_bits(),
            "{method}: eval"
        );
    }
}

/// The sim engine and the TCP coordinator share one codec session; their
/// bit meters must agree on the same workload up to codebook cadence
/// (uniform bootstrap vs lazy empirical book).
#[test]
fn engine_and_coordinator_bits_agree_qualitatively() {
    use aqsgd::coordinator::{leader::run_leader_on, run_worker, WorkerConfig};
    use aqsgd::opt::LrSchedule;
    use std::net::TcpListener;

    let iters = 60;
    let world = 2;
    let cfg = {
        let mut c = config(Method::QsgdInf, iters, ParallelMode::Serial);
        c.workers = world;
        c.seed = 11;
        c
    };
    let sim = Cluster::new(cfg).train(&mut task(world, 7));

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader = std::thread::spawn(move || run_leader_on(listener, world, iters).unwrap());
    let mut handles = Vec::new();
    for w in 0..world {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let cfg = WorkerConfig {
                addr,
                worker: w,
                world,
                method: Method::QsgdInf,
                bits: aqsgd::exchange::BitsPolicy::Fixed(3),
                bucket: 128,
                iters,
                lr: LrSchedule::paper_default(0.1, iters),
                updates: UpdateSchedule::at(vec![3, 15], 30, 15),
                momentum: 0.9,
                weight_decay: 1e-4,
                seed: 11,
                topology: aqsgd::exchange::TopologySpec::Flat,
                codec: aqsgd::quant::Codec::Huffman,
                quantize_impl: aqsgd::quant::QuantizeImpl::default(),
                pipeline: aqsgd::exchange::PipelineMode::Off,
                faults: aqsgd::sim::FaultPlan::default(),
                error_feedback: false,
                lazy: aqsgd::exchange::LazyPolicy::Off,
            };
            let mut t = task(world, 7);
            run_worker(&cfg, &mut t).unwrap()
        }));
    }
    let reports: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    leader.join().unwrap();

    let sim_bits_per_step = sim.comm_bits as f64 / iters as f64 / world as f64;
    let wire_bits_per_step = reports[0].sent_bits as f64 / iters as f64;
    let ratio = sim_bits_per_step / wire_bits_per_step;
    assert!(
        (0.5..2.0).contains(&ratio),
        "bits/step diverged: sim {sim_bits_per_step} vs wire {wire_bits_per_step}"
    );
}
