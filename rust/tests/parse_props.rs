//! Property tests for the user-facing spec grammars —
//! `BitsPolicy::parse`, `FaultPlan::parse`, and `LazyPolicy::parse`:
//! randomly generated valid values round-trip through their canonical
//! `name()` strings (`parse(name()) == self`), and malformed specs are
//! rejected with error messages that actually explain the problem.
//! Generators are hand-rolled over the repo's own seeded
//! [`aqsgd::util::Rng`] — no external property-testing dependency,
//! fully deterministic.

use aqsgd::exchange::{BitsPolicy, LazyPolicy};
use aqsgd::sim::FaultPlan;
use aqsgd::util::Rng;
use std::collections::BTreeSet;

const CASES: usize = 200;

/// A random valid `--bits-policy` value across all three variants.
fn gen_policy(rng: &mut Rng) -> BitsPolicy {
    match rng.below(3) {
        0 => BitsPolicy::parse_strict(&format!("fixed:{}", 2 + rng.below(7))).unwrap(),
        1 => {
            let mut segs = Vec::new();
            let mut step = 0usize;
            for i in 0..1 + rng.below(4) {
                if i > 0 {
                    step += 1 + rng.below(50);
                }
                segs.push(format!("{}@{}", 2 + rng.below(7), step));
            }
            BitsPolicy::parse_strict(&format!("schedule:{}", segs.join(","))).unwrap()
        }
        _ => {
            let min = 2 + rng.below(7) as u32;
            let max = min + rng.below((8 - min as usize) + 1) as u32;
            // Two-decimal targets round-trip exactly through f64
            // Display, which is all name() relies on.
            let target = (1 + rng.below(99)) as f64 / 100.0;
            BitsPolicy::parse_strict(&format!("variance:{min}-{max}@{target}")).unwrap()
        }
    }
}

/// A random valid `--faults` spec: per worker, an optional join, an
/// optional kill strictly after it, and scattered delays — never two
/// events on the same `(worker, step)`.
fn gen_fault_spec(rng: &mut Rng) -> String {
    let mut entries: Vec<String> = Vec::new();
    let mut used: BTreeSet<(usize, usize)> = BTreeSet::new();
    let world = 2 + rng.below(5);
    for w in 0..world {
        let join = if rng.below(3) == 0 {
            let s = 1 + rng.below(8);
            used.insert((w, s));
            entries.push(format!("join:{w}@{s}"));
            Some(s)
        } else {
            None
        };
        if rng.below(3) == 0 {
            let s = join.unwrap_or(0) + 1 + rng.below(8);
            if used.insert((w, s)) {
                entries.push(format!("kill:{w}@{s}"));
            }
        }
        for _ in 0..rng.below(3) {
            let s = rng.below(20);
            if used.insert((w, s)) {
                entries.push(format!("delay:{w}@{s}:{}", 1 + rng.below(500)));
            }
        }
    }
    if entries.is_empty() {
        return "none".to_string();
    }
    // Feed the parser a shuffled order: canonicalization is its job.
    for i in (1..entries.len()).rev() {
        entries.swap(i, rng.below(i + 1));
    }
    entries.join(",")
}

#[test]
fn bits_policy_roundtrips_through_name() {
    let mut rng = Rng::new(0xB1757);
    for case in 0..CASES {
        let p = gen_policy(&mut rng);
        let name = p.name();
        let back = BitsPolicy::parse_strict(&name)
            .unwrap_or_else(|e| panic!("case {case}: {name:?} failed to re-parse: {e}"));
        assert_eq!(back, p, "case {case}: parse(name()) != self for {name:?}");
        // The lossy and strict parsers agree.
        assert_eq!(BitsPolicy::parse(&name), Some(p), "case {case}: {name:?}");
    }
}

#[test]
fn fault_plan_roundtrips_through_name() {
    let mut rng = Rng::new(0xFA017);
    let mut nonempty = 0;
    for case in 0..CASES {
        let spec = gen_fault_spec(&mut rng);
        let plan = FaultPlan::parse(&spec)
            .unwrap_or_else(|e| panic!("case {case}: generated spec {spec:?} rejected: {e}"));
        let name = plan.name();
        let back = FaultPlan::parse(&name)
            .unwrap_or_else(|e| panic!("case {case}: canonical {name:?} rejected: {e}"));
        assert_eq!(back, plan, "case {case}: parse(name()) != self for {name:?}");
        // Canonical order is (step, worker, kind-rank) — verify sorted.
        let keys: Vec<(usize, usize)> =
            plan.events().iter().map(|e| (e.step, e.worker)).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted, "case {case}: events not in canonical order");
        if !plan.is_empty() {
            nonempty += 1;
        }
    }
    assert!(nonempty > CASES / 2, "generator produced mostly empty plans");
}

/// A random valid `--lazy` value across all three variants.
/// Two-decimal magnitudes round-trip exactly through f64 Display,
/// which is all `name()` relies on (same trick as the variance target).
fn gen_lazy(rng: &mut Rng) -> LazyPolicy {
    match rng.below(3) {
        0 => LazyPolicy::parse_strict("off").unwrap(),
        1 => {
            let t = (1 + rng.below(9999)) as f64 / 100.0;
            LazyPolicy::parse_strict(&format!("thresh:{t}")).unwrap()
        }
        _ => {
            let c = (1 + rng.below(999)) as f64 / 100.0;
            let k = 1 + rng.below(50);
            LazyPolicy::parse_strict(&format!("laq:{c}@{k}")).unwrap()
        }
    }
}

#[test]
fn lazy_policy_roundtrips_through_name() {
    let mut rng = Rng::new(0x1A2);
    let mut variants = [false; 3];
    for case in 0..CASES {
        let p = gen_lazy(&mut rng);
        let name = p.name();
        let back = LazyPolicy::parse_strict(&name)
            .unwrap_or_else(|e| panic!("case {case}: {name:?} failed to re-parse: {e}"));
        assert_eq!(back, p, "case {case}: parse(name()) != self for {name:?}");
        // The lossy and strict parsers agree, and the grammar is
        // case/whitespace tolerant on input while name() is canonical.
        assert_eq!(LazyPolicy::parse(&name), Some(p), "case {case}: {name:?}");
        assert_eq!(
            LazyPolicy::parse(&format!(" {} ", name.to_ascii_uppercase())),
            Some(p),
            "case {case}: {name:?}"
        );
        variants[match p {
            LazyPolicy::Off => 0,
            LazyPolicy::Thresh(_) => 1,
            LazyPolicy::Laq { .. } => 2,
        }] = true;
    }
    assert!(variants.iter().all(|&v| v), "generator missed a policy variant");
}

#[test]
fn lazy_policy_rejections_carry_diagnostics() {
    for (spec, needle) in [
        ("", "empty lazy policy"),
        ("   ", "empty lazy policy"),
        ("thresh:", "invalid lazy threshold"),
        ("thresh:big", "invalid lazy threshold"),
        ("thresh:0", "positive and finite"),
        ("thresh:-3", "positive and finite"),
        ("thresh:nan", "positive and finite"),
        ("laq:0.5", "missing '@K'"),
        ("laq:@3", "invalid laq gain"),
        ("laq:inf@3", "positive and finite"),
        ("laq:0@3", "positive and finite"),
        ("laq:0.5@", "invalid laq patience"),
        ("laq:0.5@-1", "invalid laq patience"),
        ("laq:0.5@0", "at least 1"),
        ("eager", "unknown lazy policy"),
    ] {
        let err = LazyPolicy::parse_strict(spec).unwrap_err();
        assert!(err.contains(needle), "{spec:?}: {err:?} lacks {needle:?}");
        assert_eq!(LazyPolicy::parse(spec), None, "{spec:?} must not parse");
    }
}

#[test]
fn bits_policy_rejections_carry_diagnostics() {
    for (spec, needle) in [
        ("", "empty bits policy"),
        ("fixed:1", "out of range"),
        ("fixed:9", "out of range"),
        ("fixed:three", "invalid width"),
        ("schedule:", "empty schedule"),
        ("schedule:3@0,3@0", "duplicate step"),
        ("schedule:3@0,4@9,2@4", "strictly increasing"),
        ("schedule:3@2", "step 0"),
        ("variance:5-3", "inverted variance range"),
        ("variance:2-4@nan", "positive and finite"),
        ("warp:4", "unknown bits policy"),
    ] {
        let err = BitsPolicy::parse_strict(spec).unwrap_err();
        assert!(err.contains(needle), "{spec:?}: {err:?} lacks {needle:?}");
        assert_eq!(BitsPolicy::parse(spec), None, "{spec:?} must not parse");
    }
}

#[test]
fn fault_plan_rejections_carry_diagnostics() {
    for (spec, needle) in [
        ("", "empty fault spec"),
        ("kill:0@1,", "empty fault entry"),
        ("kill", "missing ':worker@step'"),
        ("kill:0", "missing '@step'"),
        ("kill:zero@1", "invalid worker id"),
        ("kill:0@one", "invalid step"),
        ("delay:0@1", "missing ':ms'"),
        ("delay:0@1:soon", "invalid delay"),
        ("frob:0@1", "unknown fault kind 'frob'"),
        ("kill:2@4,join:2@4", "duplicate fault for worker 2 at step 4"),
        ("kill:2@4,kill:2@9", "more than one kill"),
        ("join:2@4,join:2@9", "more than one join"),
        ("kill:2@4,join:2@6", "cannot rejoin after a kill"),
    ] {
        let err = FaultPlan::parse(spec).unwrap_err();
        assert!(err.contains(needle), "{spec:?}: {err:?} lacks {needle:?}");
    }
}
