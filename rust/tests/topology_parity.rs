//! Topology-subsystem parity and metering contracts (ISSUE 2 + the
//! BackendCore parallelization of ISSUE 3):
//!
//! * **Sharded ≡ flat, bit for bit.** Sharding re-routes frames without
//!   touching payload or reduction order, so `params_hash`, per-step
//!   bits, adapted levels, and total bits must reproduce the flat
//!   engine exactly — and the sharded hop meter must sum to the flat
//!   engine's per-step totals.
//! * **Tree and ring are per-seed goldens.** Their schedules re-quantize
//!   partial aggregates (tree: at the leader level; ring: every
//!   reduce-scatter hop), so the reduction order necessarily differs
//!   from flat; the contract is bit-determinism per seed, replica
//!   agreement, and a trajectory that still learns.
//! * **`--parallel` changes nothing but wall time.** Every backend must
//!   produce bit-identical runs (`params_hash`, per-step bits, levels)
//!   under `--parallel on` and `--parallel off` — the DESIGN.md §8
//!   BackendCore contract.
//! * **Hop self-consistency.** For every topology, Σ per-hop metered
//!   bits equals the step total returned by `exchange()` and
//!   accumulated by the meter — and hop records appear in schedule
//!   order regardless of lane scheduling (never in thread-completion
//!   order).
//! * **Selectable everywhere.** `--topology` flows through the sim CLI
//!   config and the TCP coordinator (leader relay modes + workers).
//! * **`--pipeline overlap ≡ off`, bit for bit (ISSUE 9).** Overlap only
//!   moves wall clock: on every topology × `--parallel` mode the
//!   trajectory (`params_hash`, per-step bits + hashes, comm_bits),
//!   the modeled `comm_time`, and the raw-backend hop logs must equal
//!   the serial schedule exactly; only `hidden_time` may differ. The
//!   same holds on the TCP wire path. `stale:1` is a per-seed golden:
//!   deterministic, step-0 bits equal to `off`, trajectory diverging
//!   from step 1 once the one-step-late aggregate lands.

mod common;

use aqsgd::config::RunConfig;
use aqsgd::coordinator::leader::run_leader_topo;
use aqsgd::coordinator::{run_worker, WorkerConfig};
use aqsgd::data::Blobs;
use aqsgd::exchange::{
    make_backend, BitsPolicy, ExchangeBackend, ExchangeConfig, ParallelMode, PipelineMode,
    TopologySpec,
};
use aqsgd::model::{Mlp, MlpTask};
use aqsgd::opt::{LrSchedule, UpdateSchedule};
use aqsgd::quant::{Codec, Method};
use aqsgd::sim::{Cluster, ClusterConfig, FaultPlan, NetworkModel};
use aqsgd::util::Rng;

fn task(workers: usize, seed: u64) -> MlpTask {
    let blobs = Blobs::generate(8, 4, 1600, 400, 1.0, seed);
    MlpTask::new(Mlp::new(vec![8, 32, 4]), blobs, 32, workers, seed)
}

fn config(method: Method, iters: usize, topology: TopologySpec) -> ClusterConfig {
    let mut cfg = ClusterConfig::paper_default(method, iters);
    cfg.bucket = 128;
    cfg.eval_every = 0;
    cfg.seed = 5;
    cfg.updates = UpdateSchedule::at(vec![3, 15], 30, 15);
    cfg.topology = topology;
    cfg
}

#[test]
fn sharded_reproduces_flat_bit_for_bit() {
    for method in [Method::Alq, Method::NuqSgd] {
        let flat = Cluster::new(config(method, 40, TopologySpec::Flat)).train(&mut task(4, 3));
        for shards in [2usize, 3] {
            let rec = Cluster::new(config(method, 40, TopologySpec::Sharded(shards)))
                .train(&mut task(4, 3));
            assert_eq!(rec.params_hash, flat.params_hash, "{method} S={shards}");
            assert_eq!(rec.comm_bits, flat.comm_bits, "{method} S={shards}");
            assert_eq!(
                rec.steps.iter().map(|s| s.bits).collect::<Vec<_>>(),
                flat.steps.iter().map(|s| s.bits).collect::<Vec<_>>(),
                "{method} S={shards} per-step bits"
            );
            assert_eq!(rec.final_levels, flat.final_levels, "{method} S={shards}");
        }
    }
}

#[test]
fn tree_and_ring_are_per_seed_goldens() {
    for topology in [TopologySpec::Tree(2), TopologySpec::Ring] {
        let a = Cluster::new(config(Method::QsgdInf, 30, topology)).train(&mut task(4, 3));
        let b = Cluster::new(config(Method::QsgdInf, 30, topology)).train(&mut task(4, 3));
        // Bit-deterministic per seed.
        assert_eq!(a.params_hash, b.params_hash, "{}", topology.name());
        assert_eq!(a.comm_bits, b.comm_bits, "{}", topology.name());
        assert_eq!(a.final_levels, b.final_levels, "{}", topology.name());
        // A different seed is a different run.
        let mut cfg = config(Method::QsgdInf, 30, topology);
        cfg.seed = 6;
        let c = Cluster::new(cfg).train(&mut task(4, 3));
        assert_ne!(a.params_hash, c.params_hash, "{}", topology.name());
        // Re-quantized partials: a genuinely different reduction order
        // than flat (which is why these are goldens, not flat parity).
        let flat = Cluster::new(config(Method::QsgdInf, 30, TopologySpec::Flat))
            .train(&mut task(4, 3));
        assert_ne!(a.params_hash, flat.params_hash, "{}", topology.name());
    }
}

#[test]
fn tree_and_ring_still_learn() {
    for topology in [TopologySpec::Tree(2), TopologySpec::Ring] {
        let mut cfg = config(Method::QsgdInf, 300, topology);
        cfg.updates = UpdateSchedule::at(vec![1, 25], 100, 25);
        let rec = Cluster::new(cfg).train(&mut task(4, 7));
        let first = rec.steps.first().unwrap().train_loss;
        let last: f64 =
            rec.steps.iter().rev().take(10).map(|s| s.train_loss).sum::<f64>() / 10.0;
        assert!(
            last < first * 0.7,
            "{}: loss {first} -> {last}",
            topology.name()
        );
        assert!(rec.final_eval.accuracy > 0.5, "{}", topology.name());
    }
}

/// Σ per-hop bits == step total == meter accumulation, for every
/// topology, on raw backends driven directly — in both lane-scheduling
/// modes, with hop records in deterministic (schedule) order: the
/// parallel run's hop label sequence and per-hop bits must equal the
/// serial run's exactly, never thread-completion order.
#[test]
fn hop_bits_sum_to_step_totals_for_every_topology() {
    let d = 1500; // 11 buckets of 128 + tail 92
    let workers = 4;
    let mut rng = Rng::new(1);
    let grads: Vec<Vec<f32>> = (0..workers)
        .map(|_| (0..d).map(|_| (rng.normal() * 0.1) as f32).collect())
        .collect();
    for topology in [
        TopologySpec::Flat,
        TopologySpec::Sharded(3),
        TopologySpec::Tree(2),
        TopologySpec::Ring,
    ] {
        let cfg = |parallel| ExchangeConfig {
            method: Method::Alq,
            workers,
            bits: BitsPolicy::Fixed(3),
            bucket: 128,
            seed: 9,
            network: NetworkModel::paper_testbed(),
            parallel,
            codec: Codec::Huffman,
            quantize_impl: aqsgd::quant::QuantizeImpl::default(),
        };
        let mut backend = make_backend(cfg(ParallelMode::Serial), topology);
        let mut par_backend = make_backend(cfg(ParallelMode::Parallel), topology);
        let mut agg = vec![0.0f32; d];
        let mut accumulated = 0u64;
        for step in 0..8 {
            if step == 4 {
                backend.adapt(&grads);
                par_backend.adapt(&grads);
            }
            let bits = backend.exchange(step, &grads, &mut agg);
            let par_bits = par_backend.exchange(step, &grads, &mut agg);
            assert_eq!(bits, par_bits, "{} step {step}", topology.name());
            let hops = backend.last_hops();
            assert!(!hops.is_empty(), "{}", topology.name());
            assert_eq!(
                hops.iter().map(|h| h.bits).sum::<u64>(),
                bits,
                "{} step {step}",
                topology.name()
            );
            assert!(
                hops.iter().all(|h| h.seconds >= 0.0),
                "{}",
                topology.name()
            );
            // Hop determinism: parallel lanes must report the same hop
            // sequence (labels AND bits) as the serial schedule.
            let serial_hops: Vec<(&str, u64)> =
                hops.iter().map(|h| (h.label.as_str(), h.bits)).collect();
            let parallel_hops: Vec<(&str, u64)> = par_backend
                .last_hops()
                .iter()
                .map(|h| (h.label.as_str(), h.bits))
                .collect();
            assert_eq!(
                serial_hops,
                parallel_hops,
                "{} step {step}: hop records must be in schedule order",
                topology.name()
            );
            accumulated += bits;
        }
        assert_eq!(
            backend.meter().total_bits,
            accumulated,
            "{}",
            topology.name()
        );
        assert!(backend.meter().total_time > 0.0, "{}", topology.name());
    }
}

/// The ISSUE 3 acceptance criterion: every backend is bit-identical
/// between `--parallel on` and `--parallel off` over a full training
/// run — `params_hash`, per-step bits, total bits, and adapted levels.
#[test]
fn every_backend_is_bit_identical_across_parallel_modes() {
    for topology in [
        TopologySpec::Flat,
        TopologySpec::Sharded(3),
        TopologySpec::Tree(2),
        TopologySpec::Ring,
    ] {
        let run = |mode| {
            let mut cfg = config(Method::Alq, 40, topology);
            cfg.parallel = mode;
            Cluster::new(cfg).train(&mut task(4, 3))
        };
        let serial = run(ParallelMode::Serial);
        let parallel = run(ParallelMode::Parallel);
        assert_eq!(
            serial.params_hash,
            parallel.params_hash,
            "{}: params_hash",
            topology.name()
        );
        assert_eq!(
            serial.comm_bits,
            parallel.comm_bits,
            "{}: comm_bits",
            topology.name()
        );
        assert_eq!(
            serial.steps.iter().map(|s| s.bits).collect::<Vec<_>>(),
            parallel.steps.iter().map(|s| s.bits).collect::<Vec<_>>(),
            "{}: per-step bits",
            topology.name()
        );
        assert_eq!(
            serial.final_levels,
            parallel.final_levels,
            "{}: levels",
            topology.name()
        );
    }
}

/// The satellite requirement spelled out: the new sharded backend's
/// per-hop meter sums to the *flat engine's* existing per-step totals.
#[test]
fn sharded_hops_sum_to_flat_engine_step_totals() {
    let d = 2000;
    let workers = 4;
    let mut rng = Rng::new(2);
    let grads: Vec<Vec<f32>> = (0..workers)
        .map(|_| (0..d).map(|_| (rng.normal() * 0.1) as f32).collect())
        .collect();
    let cfg = ExchangeConfig {
        method: Method::NuqSgd,
        workers,
        bits: BitsPolicy::Fixed(3),
        bucket: 128,
        seed: 11,
        network: NetworkModel::paper_testbed(),
        parallel: ParallelMode::Serial,
        codec: Codec::Huffman,
        quantize_impl: aqsgd::quant::QuantizeImpl::default(),
    };
    let mut flat = make_backend(cfg.clone(), TopologySpec::Flat);
    let mut shrd = make_backend(cfg, TopologySpec::Sharded(4));
    let mut agg = vec![0.0f32; d];
    for step in 0..6 {
        let flat_bits = flat.exchange(step, &grads, &mut agg);
        let _ = shrd.exchange(step, &grads, &mut agg);
        let shard_hop_sum: u64 = shrd.last_hops().iter().map(|h| h.bits).sum();
        assert_eq!(shard_hop_sum, flat_bits, "step {step}");
    }
}

#[test]
fn ring_has_the_analytical_stage_structure() {
    let d = 1280; // exactly 10 buckets, no tail
    for workers in [4usize, 8] {
        let mut rng = Rng::new(3);
        let grads: Vec<Vec<f32>> = (0..workers)
            .map(|_| (0..d).map(|_| (rng.normal() * 0.1) as f32).collect())
            .collect();
        let cfg = ExchangeConfig {
            method: Method::QsgdInf,
            workers,
            bits: BitsPolicy::Fixed(3),
            bucket: 128,
            seed: 4,
            network: NetworkModel::paper_testbed(),
            parallel: ParallelMode::Serial,
            codec: Codec::Huffman,
            quantize_impl: aqsgd::quant::QuantizeImpl::default(),
        };
        let mut ring = make_backend(cfg, TopologySpec::Ring);
        let mut agg = vec![0.0f32; d];
        ring.exchange(0, &grads, &mut agg);
        let hops = ring.last_hops();
        // 2(M−1) stages, half reduce-scatter, half all-gather.
        assert_eq!(hops.len(), 2 * (workers - 1), "M={workers}");
        assert_eq!(
            hops.iter()
                .filter(|h| h.label.starts_with("reduce-scatter"))
                .count(),
            workers - 1
        );
        assert_eq!(
            hops.iter()
                .filter(|h| h.label.starts_with("all-gather"))
                .count(),
            workers - 1
        );
    }
}

#[test]
fn topology_selectable_from_the_sim_cli_config() {
    let args = |s: &str| s.split_whitespace().map(String::from).collect::<Vec<_>>();
    let c = RunConfig::from_args(&args("--topology sharded:4")).unwrap();
    assert_eq!(c.cluster().topology, TopologySpec::Sharded(4));
    let c = RunConfig::from_args(&args("--topology tree:2 --iters 1")).unwrap();
    assert_eq!(c.cluster().topology, TopologySpec::Tree(2));
    let c = RunConfig::from_args(&args("--topology ring")).unwrap();
    assert_eq!(c.cluster().topology, TopologySpec::Ring);
    assert!(RunConfig::from_args(&args("--topology hypercube")).is_err());
    // The codec ablation rides the same config surface.
    let c = RunConfig::from_args(&args("--codec elias")).unwrap();
    assert_eq!(c.cluster().codec, Codec::Elias);
}

fn spawn_tcp_pipeline(
    method: Method,
    iters: usize,
    world: usize,
    topology: TopologySpec,
    pipeline: PipelineMode,
) -> Vec<aqsgd::coordinator::WorkerReport> {
    let (listener, addr) = common::free_listener();
    let leader =
        std::thread::spawn(move || run_leader_topo(listener, world, iters, topology).unwrap());
    let mut handles = Vec::new();
    for w in 0..world {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let cfg = WorkerConfig {
                addr,
                worker: w,
                world,
                method,
                bits: BitsPolicy::Fixed(3),
                bucket: 128,
                iters,
                lr: LrSchedule::paper_default(0.1, iters),
                updates: UpdateSchedule::at(vec![3, 15], 30, 15),
                momentum: 0.9,
                weight_decay: 1e-4,
                seed: 42,
                topology,
                codec: Codec::Huffman,
                quantize_impl: aqsgd::quant::QuantizeImpl::default(),
                pipeline,
                faults: FaultPlan::default(),
                error_feedback: false,
                lazy: aqsgd::exchange::LazyPolicy::Off,
            };
            let blobs = Blobs::generate(8, 4, 1600, 400, 1.0, 7);
            let mut t = MlpTask::new(Mlp::new(vec![8, 32, 4]), blobs, 32, world, 7);
            run_worker(&cfg, &mut t).unwrap()
        }));
    }
    let reports = handles.into_iter().map(|h| h.join().unwrap()).collect();
    leader.join().unwrap();
    reports
}

fn spawn_tcp(
    method: Method,
    iters: usize,
    world: usize,
    topology: TopologySpec,
) -> Vec<aqsgd::coordinator::WorkerReport> {
    spawn_tcp_pipeline(method, iters, world, topology, PipelineMode::Off)
}

/// `--topology` is selectable on the TCP coordinator, and the sharded
/// relay reproduces the flat relay bit for bit (acceptance criterion).
#[test]
fn tcp_topologies_are_selectable_and_sharded_matches_flat() {
    let flat = spawn_tcp(Method::Alq, 30, 4, TopologySpec::Flat);
    let sharded = spawn_tcp(Method::Alq, 30, 4, TopologySpec::Sharded(3));
    let tree = spawn_tcp(Method::Alq, 30, 4, TopologySpec::Tree(2));
    for reports in [&flat, &sharded, &tree] {
        for r in reports.iter() {
            assert_eq!(r.params_hash, reports[0].params_hash, "replica divergence");
        }
    }
    assert_eq!(flat[0].params_hash, sharded[0].params_hash);
    assert_eq!(flat[0].final_levels, sharded[0].final_levels);
    for (f, s) in flat.iter().zip(&sharded) {
        assert_eq!(f.sent_bits, s.sent_bits);
    }
    // Tree replicas agree with each other but follow their own golden.
    assert_ne!(tree[0].params_hash, flat[0].params_hash);
}

/// ISSUE 4 acceptance: `--bits-policy fixed:B` is provably
/// behavior-preserving. The flat engine is pinned to the pre-refactor
/// seed loop by the oracle in `exchange_parity.rs`; here every topology
/// × `--parallel` mode must produce the *same* trajectory whether the
/// constant width is expressed as `fixed:3` or routed through the full
/// dynamic machinery (`schedule:3@0`, `variance:3-3`) — params_hash,
/// per-step bits, per-step widths, adapted levels, and total bits all
/// equal, so the per-step controller + bank provably change nothing at
/// constant width.
#[test]
fn fixed_policy_is_bit_identical_to_dynamic_machinery_at_constant_width() {
    for topology in [
        TopologySpec::Flat,
        TopologySpec::Sharded(2),
        TopologySpec::Tree(2),
        TopologySpec::Ring,
    ] {
        for parallel in [ParallelMode::Serial, ParallelMode::Parallel] {
            let run = |bits: BitsPolicy| {
                let mut cfg = config(Method::Alq, 40, topology);
                cfg.parallel = parallel;
                cfg.bits = bits;
                Cluster::new(cfg).train(&mut task(4, 3))
            };
            let fixed = run(BitsPolicy::Fixed(3));
            let schedule = run(BitsPolicy::parse("schedule:3@0").unwrap());
            let variance = run(BitsPolicy::parse("variance:3-3").unwrap());
            for (name, rec) in [("schedule:3@0", &schedule), ("variance:3-3", &variance)] {
                let ctx = format!("{} {} {name}", topology.name(), parallel.name());
                assert_eq!(rec.params_hash, fixed.params_hash, "{ctx}: params_hash");
                assert_eq!(rec.comm_bits, fixed.comm_bits, "{ctx}: comm_bits");
                assert_eq!(
                    rec.steps.iter().map(|s| s.bits).collect::<Vec<_>>(),
                    fixed.steps.iter().map(|s| s.bits).collect::<Vec<_>>(),
                    "{ctx}: per-step bits"
                );
                assert_eq!(rec.final_levels, fixed.final_levels, "{ctx}: levels");
            }
            assert!(fixed.steps.iter().all(|s| s.width == 3));
            assert!(variance.steps.iter().all(|s| s.width == 3));
        }
    }
}

/// The hop log is part of the fixed-width regression surface: expressing
/// the same constant width through the dynamic machinery must reproduce
/// the exact per-hop label/bit sequence on every topology.
#[test]
fn fixed_policy_hop_logs_match_dynamic_machinery_at_constant_width() {
    let d = 1500;
    let workers = 4;
    let mut rng = Rng::new(4);
    let grads: Vec<Vec<f32>> = (0..workers)
        .map(|_| (0..d).map(|_| (rng.normal() * 0.1) as f32).collect())
        .collect();
    for topology in [
        TopologySpec::Flat,
        TopologySpec::Sharded(3),
        TopologySpec::Tree(2),
        TopologySpec::Ring,
    ] {
        let cfg = |bits: BitsPolicy| ExchangeConfig {
            method: Method::Alq,
            workers,
            bits,
            bucket: 128,
            seed: 9,
            network: NetworkModel::paper_testbed(),
            parallel: ParallelMode::Serial,
            codec: Codec::Huffman,
            quantize_impl: aqsgd::quant::QuantizeImpl::default(),
        };
        let mut fixed = make_backend(cfg(BitsPolicy::Fixed(3)), topology);
        let mut banked =
            make_backend(cfg(BitsPolicy::parse("variance:3-3").unwrap()), topology);
        let mut agg = vec![0.0f32; d];
        for step in 0..6 {
            if step == 4 {
                fixed.adapt(&grads);
                banked.adapt(&grads);
            }
            let bf = fixed.exchange(step, &grads, &mut agg);
            let bb = banked.exchange(step, &grads, &mut agg);
            assert_eq!(bf, bb, "{} step {step} bits", topology.name());
            let hf: Vec<(String, u64)> = fixed
                .last_hops()
                .iter()
                .map(|h| (h.label.clone(), h.bits))
                .collect();
            let hb: Vec<(String, u64)> = banked
                .last_hops()
                .iter()
                .map(|h| (h.label.clone(), h.bits))
                .collect();
            assert_eq!(hf, hb, "{} step {step} hop log", topology.name());
        }
    }
}

/// The `variance` policy saves bits for real: pinned to a permissive
/// target it settles on the narrowest width, and the run meters strictly
/// fewer total bits than a fixed run at the widest width while still
/// training (per-step bits are measured payload, not nominal width·d).
#[test]
fn variance_policy_meters_fewer_bits_than_fixed_at_max_width() {
    let run = |bits: BitsPolicy| {
        let mut cfg = config(Method::Alq, 100, TopologySpec::Flat);
        cfg.bits = bits;
        Cluster::new(cfg).train(&mut task(4, 3))
    };
    let fixed4 = run(BitsPolicy::Fixed(4));
    let adaptive = run(BitsPolicy::parse("variance:2-4@1000000").unwrap());
    // The permissive target lets the controller drop to the floor as
    // soon as it has one observation.
    assert!(adaptive.steps.iter().skip(1).all(|s| s.width == 2));
    assert!(
        adaptive.comm_bits < fixed4.comm_bits,
        "variance policy should undercut fixed:4 ({} vs {})",
        adaptive.comm_bits,
        fixed4.comm_bits
    );
    // Still a working training run, not a degenerate one.
    let first = adaptive.steps.first().unwrap().train_loss;
    let last: f64 = adaptive.steps.iter().rev().take(10).map(|s| s.train_loss).sum::<f64>() / 10.0;
    assert!(last < first, "loss should still fall: {first} -> {last}");
}

/// `--bits-policy` is selectable from the sim CLI config, and malformed
/// policies are config errors.
#[test]
fn bits_policy_selectable_from_the_sim_cli_config() {
    let args = |s: &str| s.split_whitespace().map(String::from).collect::<Vec<_>>();
    let c = RunConfig::from_args(&args("--bits-policy schedule:4@0,2@50")).unwrap();
    assert_eq!(
        c.cluster().bits,
        BitsPolicy::parse("schedule:4@0,2@50").unwrap()
    );
    let c = RunConfig::from_args(&args("--bits-policy variance:2-4")).unwrap();
    assert_eq!(c.cluster().bits, BitsPolicy::parse("variance:2-4").unwrap());
    assert!(RunConfig::from_args(&args("--bits-policy schedule:2@9")).is_err());
}

/// The ISSUE 9 acceptance criterion: `--pipeline overlap` moves only
/// wall clock. On every topology × `--parallel` mode the trajectory
/// (`params_hash`, per-step bits + per-step hashes, total bits, adapted
/// levels) and the modeled `comm_time` are bit-identical to `off`; the
/// only permitted difference is the hidden-seconds ledger — nonzero
/// wherever an encode phase exists to hide wire time behind (flat,
/// sharded, tree), and exactly zero on ring, whose strict stage chain
/// has no independent encode to overlap (see `topology/ring.rs` docs).
#[test]
fn overlap_is_bit_identical_to_off_for_every_topology_and_parallel_mode() {
    for topology in [
        TopologySpec::Flat,
        TopologySpec::Sharded(3),
        TopologySpec::Tree(2),
        TopologySpec::Ring,
    ] {
        for parallel in [ParallelMode::Serial, ParallelMode::Parallel] {
            let run = |pipeline: PipelineMode| {
                let mut cfg = config(Method::Alq, 40, topology);
                cfg.parallel = parallel;
                cfg.pipeline = pipeline;
                Cluster::new(cfg).train(&mut task(4, 3))
            };
            let off = run(PipelineMode::Off);
            let overlap = run(PipelineMode::Overlap);
            let ctx = format!("{} {}", topology.name(), parallel.name());
            assert_eq!(overlap.params_hash, off.params_hash, "{ctx}: params_hash");
            assert_eq!(overlap.comm_bits, off.comm_bits, "{ctx}: comm_bits");
            assert_eq!(
                overlap
                    .steps
                    .iter()
                    .map(|s| (s.bits, s.params_hash, s.width))
                    .collect::<Vec<_>>(),
                off.steps
                    .iter()
                    .map(|s| (s.bits, s.params_hash, s.width))
                    .collect::<Vec<_>>(),
                "{ctx}: per-step trajectory"
            );
            assert_eq!(overlap.final_levels, off.final_levels, "{ctx}: levels");
            // The modeled wire time is untouched — overlap hides
            // seconds, it does not re-price them.
            assert_eq!(
                overlap.comm_time.to_bits(),
                off.comm_time.to_bits(),
                "{ctx}: comm_time"
            );
            assert_eq!(off.hidden_time, 0.0, "{ctx}: off must hide nothing");
            if topology == TopologySpec::Ring {
                assert_eq!(overlap.hidden_time, 0.0, "{ctx}: ring overlap is inert");
            } else {
                assert!(overlap.hidden_time > 0.0, "{ctx}: overlap hid nothing");
            }
            assert!(
                overlap.hidden_time <= overlap.comm_time + 1e-12,
                "{ctx}: hidden exceeds modeled comm"
            );
            assert!(
                overlap.wall_time() <= overlap.compute_time + overlap.comm_time + 1e-12,
                "{ctx}: wall time accounting"
            );
        }
    }
}

/// Hop logs are part of the overlap-parity surface: raw backends driven
/// directly must report the exact same per-hop (label, bits, modeled
/// seconds) sequence with the pipeline on, and the wire meter must
/// price every step identically — only the hidden ledger may move.
#[test]
fn overlap_hop_logs_match_off_on_raw_backends() {
    let d = 1500;
    let workers = 4;
    let mut rng = Rng::new(6);
    let grads: Vec<Vec<f32>> = (0..workers)
        .map(|_| (0..d).map(|_| (rng.normal() * 0.1) as f32).collect())
        .collect();
    for topology in [
        TopologySpec::Flat,
        TopologySpec::Sharded(3),
        TopologySpec::Tree(2),
        TopologySpec::Ring,
    ] {
        let cfg = ExchangeConfig {
            method: Method::Alq,
            workers,
            bits: BitsPolicy::Fixed(3),
            bucket: 128,
            seed: 9,
            network: NetworkModel::paper_testbed(),
            parallel: ParallelMode::Serial,
            codec: Codec::Huffman,
            quantize_impl: aqsgd::quant::QuantizeImpl::default(),
        };
        let mut off = make_backend(cfg.clone(), topology);
        let mut overlap = make_backend(cfg, topology);
        overlap.core_mut().set_pipeline(PipelineMode::Overlap);
        let mut agg = vec![0.0f32; d];
        for step in 0..8 {
            if step == 4 {
                off.adapt(&grads);
                overlap.adapt(&grads);
            }
            let b_off = off.exchange(step, &grads, &mut agg);
            let b_ov = overlap.exchange(step, &grads, &mut agg);
            assert_eq!(b_off, b_ov, "{} step {step} bits", topology.name());
            let log = |b: &Box<dyn ExchangeBackend>| {
                b.last_hops()
                    .iter()
                    .map(|h| (h.label.clone(), h.bits, h.seconds.to_bits()))
                    .collect::<Vec<_>>()
            };
            assert_eq!(
                log(&off),
                log(&overlap),
                "{} step {step} hop log",
                topology.name()
            );
        }
        assert_eq!(off.meter().total_bits, overlap.meter().total_bits);
        assert_eq!(
            off.meter().total_time.to_bits(),
            overlap.meter().total_time.to_bits(),
            "{}: modeled wire seconds must not be re-priced",
            topology.name()
        );
        assert_eq!(off.meter().hidden_seconds, 0.0, "{}", topology.name());
        if topology == TopologySpec::Ring {
            assert_eq!(overlap.meter().hidden_seconds, 0.0, "ring hides nothing");
        } else {
            assert!(
                overlap.meter().hidden_seconds > 0.0,
                "{}: encode ledger never fed the meter",
                topology.name()
            );
        }
    }
}

/// `stale:1` is a per-seed golden, not an `off`-parity mode: two runs at
/// one seed are bit-identical, step 0 meters the same bits as `off`
/// (the first gradients see identical parameters), and the trajectory
/// diverges from step 0's update on — the aggregate lands a step late.
#[test]
fn stale_pipeline_is_a_per_seed_golden_trajectory() {
    for topology in [TopologySpec::Flat, TopologySpec::Tree(2)] {
        let run = |pipeline: PipelineMode, seed: u64| {
            let mut cfg = config(Method::Alq, 40, topology);
            cfg.pipeline = pipeline;
            cfg.seed = seed;
            Cluster::new(cfg).train(&mut task(4, 3))
        };
        let a = run(PipelineMode::Stale, 5);
        let b = run(PipelineMode::Stale, 5);
        let ctx = topology.name();
        assert_eq!(a.params_hash, b.params_hash, "{ctx}: stale determinism");
        assert_eq!(a.comm_bits, b.comm_bits, "{ctx}");
        assert_eq!(
            a.steps
                .iter()
                .map(|s| (s.bits, s.params_hash))
                .collect::<Vec<_>>(),
            b.steps
                .iter()
                .map(|s| (s.bits, s.params_hash))
                .collect::<Vec<_>>(),
            "{ctx}: stale per-step golden"
        );
        assert_eq!(a.final_levels, b.final_levels, "{ctx}");
        // A different seed is a different golden.
        let c = run(PipelineMode::Stale, 6);
        assert_ne!(a.params_hash, c.params_hash, "{ctx}");
        // Step 0 quantizes the same gradients as off (identical initial
        // params), so it meters the same bits — but its update is
        // deferred, so the post-step hashes already differ.
        let off = run(PipelineMode::Off, 5);
        assert_eq!(a.steps[0].bits, off.steps[0].bits, "{ctx}: step-0 bits");
        assert_ne!(
            a.steps[0].params_hash, off.steps[0].params_hash,
            "{ctx}: stale defers the first update"
        );
        assert_ne!(a.params_hash, off.params_hash, "{ctx}: stale is its own run");
        // Staleness buys real overlap: comm hides behind next-step
        // compute.
        assert!(a.hidden_time > 0.0, "{ctx}: stale hid nothing");
        assert!(a.hidden_time <= a.comm_time + 1e-12, "{ctx}");
    }
}

/// TCP wire-path parity: the overlap sender (encode shard k+1 while
/// frame k is on the wire) must leave every replica's trajectory,
/// frame accounting, and per-step fingerprints bit-identical to the
/// serial sender — on the sharded relay where it actually double
/// buffers, and on flat where it is structurally a no-op.
#[test]
fn tcp_overlap_is_bit_identical_to_off() {
    let off = spawn_tcp_pipeline(Method::Alq, 30, 4, TopologySpec::Sharded(3), PipelineMode::Off);
    let overlap =
        spawn_tcp_pipeline(Method::Alq, 30, 4, TopologySpec::Sharded(3), PipelineMode::Overlap);
    for (w, (o, v)) in off.iter().zip(&overlap).enumerate() {
        assert_eq!(o.params_hash, v.params_hash, "worker {w}: params_hash");
        assert_eq!(o.sent_bits, v.sent_bits, "worker {w}: sent_bits");
        assert_eq!(o.final_levels, v.final_levels, "worker {w}: levels");
        assert_eq!(o.step_records, v.step_records, "worker {w}: step records");
    }
    // Overlap on the flat relay (single frame per step — nothing to
    // pipeline) still runs and still matches: sharded ≡ flat composes
    // with overlap ≡ off.
    let flat = spawn_tcp_pipeline(Method::Alq, 30, 4, TopologySpec::Flat, PipelineMode::Overlap);
    assert_eq!(flat[0].params_hash, off[0].params_hash);
    assert_eq!(flat[0].final_levels, off[0].final_levels);
}
