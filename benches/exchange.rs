//! Bench: the unified exchange engine — serial vs thread-parallel worker
//! lanes on a large gradient (the acceptance measurement for the
//! multi-lane refactor: parallel must beat the seed's serial loop for
//! M ≥ 4). Both schedules are bit-identical by construction (see
//! rust/tests/exchange_parity.rs); this measures only wall clock.
//!
//! Emits the `exchange` section of BENCH_hotloop.json (steps/s serial
//! vs parallel per method × worker count, plus modeled per-hop seconds
//! from the flat topology backend). This binary runs last in the ci.sh
//! bench chain, so when `BENCH_JSON` is set it also validates that the
//! full document carries every section the schema promises.

mod bench_util;
use aqsgd::exchange::{make_backend, ExchangeConfig, GradientExchange, ParallelMode, TopologySpec};
use aqsgd::quant::Method;
use aqsgd::sim::NetworkModel;
use aqsgd::util::json::Json;
use aqsgd::util::Rng;
use bench_util::{
    emit_section, header, load_doc, report, sized, throughput_row, time_per_call, window_ms,
    BENCH_SCHEMA,
};

fn config(method: Method, workers: usize, mode: ParallelMode) -> ExchangeConfig {
    ExchangeConfig {
        method,
        workers,
        bits: aqsgd::exchange::BitsPolicy::Fixed(3),
        bucket: 8192,
        seed: 1,
        network: NetworkModel::paper_testbed(),
        parallel: mode,
        codec: aqsgd::quant::Codec::Huffman,
        quantize_impl: aqsgd::quant::QuantizeImpl::default(),
    }
}

fn engine(method: Method, workers: usize, mode: ParallelMode) -> GradientExchange {
    GradientExchange::new(config(method, workers, mode))
}

fn main() {
    let d = sized(1 << 20, 1 << 14);
    let wms = window_ms(400);

    let mut section = Json::obj();
    section.insert("coords", Json::Num(d as f64));
    let mut methods = Json::obj();

    for method in [Method::QsgdInf, Method::Alq] {
        let mut per_workers = Json::obj();
        for &workers in &[2usize, 4, 8] {
            header(&format!(
                "exchange step: {} @ 3 bits, d = {d}, M = {workers}",
                method.name()
            ));
            let mut rng = Rng::new(7);
            let grads: Vec<Vec<f32>> = (0..workers)
                .map(|_| (0..d).map(|_| (rng.normal() * 0.01) as f32).collect())
                .collect();
            let mut agg = vec![0.0f32; d];

            let mut times = [0.0f64; 2];
            for (i, mode) in [ParallelMode::Serial, ParallelMode::Parallel]
                .into_iter()
                .enumerate()
            {
                let mut eng = engine(method, workers, mode);
                let mut step = 0usize;
                times[i] = time_per_call(
                    || {
                        eng.exchange(step, &grads, &mut agg);
                        step += 1;
                    },
                    wms,
                );
                report(&format!("M={workers} {}", mode.name()), times[i], d * workers);
            }
            println!(
                "    parallel speedup over serial at M={workers}: {:.2}x",
                times[0] / times[1]
            );

            // Sanity: identical bits either way (full parity is tested in
            // rust/tests/exchange_parity.rs).
            let mut a = engine(method, workers, ParallelMode::Serial);
            let mut b = engine(method, workers, ParallelMode::Parallel);
            let bits_a = a.exchange(0, &grads, &mut agg);
            let bits_b = b.exchange(0, &grads, &mut agg);
            assert_eq!(bits_a, bits_b, "schedules must meter identical bits");

            let mut row = Json::obj();
            let mut serial = throughput_row(times[0], d * workers);
            serial.insert("steps_per_sec", Json::Num(1.0 / times[0]));
            let mut parallel = throughput_row(times[1], d * workers);
            parallel.insert("steps_per_sec", Json::Num(1.0 / times[1]));
            row.insert("serial", serial);
            row.insert("parallel", parallel);
            row.insert("speedup", Json::Num(times[0] / times[1]));
            row.insert("bits_per_step", Json::Num(bits_a as f64));
            per_workers.insert(&workers.to_string(), row);
        }
        methods.insert(method.name(), per_workers);
    }
    section.insert("methods", methods);

    // -- modeled per-hop cost on the flat topology backend ---------------
    header("per-hop cost: flat topology backend, M = 4");
    {
        let workers = 4;
        let mut rng = Rng::new(9);
        let grads: Vec<Vec<f32>> = (0..workers)
            .map(|_| (0..d).map(|_| (rng.normal() * 0.01) as f32).collect())
            .collect();
        let mut agg = vec![0.0f32; d];
        let mut backend = make_backend(
            config(Method::Alq, workers, ParallelMode::Serial),
            TopologySpec::Flat,
        );
        let mut step = 0usize;
        let wall = time_per_call(
            || {
                backend.exchange(step, &grads, &mut agg);
                step += 1;
            },
            wms,
        );
        let hops = backend.last_hops().len().max(1);
        let steps = backend.meter().steps.max(1);
        let modeled_per_hop = backend.meter().total_time / steps as f64 / hops as f64;
        println!(
            "flat M={workers}: {hops} hops/step, wall {:.1} µs/hop, modeled net {:.3} ms/hop",
            wall * 1e6 / hops as f64,
            modeled_per_hop * 1e3
        );
        let mut hop = Json::obj();
        hop.insert("topology", Json::Str("flat".into()));
        hop.insert("workers", Json::Num(workers as f64));
        hop.insert("hops_per_step", Json::Num(hops as f64));
        hop.insert("wall_secs_per_hop", Json::Num(wall / hops as f64));
        hop.insert("modeled_secs_per_hop", Json::Num(modeled_per_hop));
        section.insert("per_hop", hop);
    }

    emit_section("exchange", section);

    // -- final document validation (this binary runs last in ci.sh) ------
    if std::env::var_os("BENCH_JSON").is_some() {
        let doc = load_doc().expect("BENCH_JSON must exist and parse after emission");
        assert_eq!(
            doc.get("schema").and_then(Json::as_str),
            Some(BENCH_SCHEMA),
            "schema tag mismatch"
        );
        for key in ["meta", "quantize", "encode", "exchange"] {
            assert!(
                doc.get(key).is_some(),
                "BENCH_JSON is missing section {key:?} — run the quantize and encode \
                 benches before this one"
            );
        }
        // Spot-check the keys the EXPERIMENTS.md tables read.
        doc.req("quantize").req("widths").req("4").req("speedup");
        doc.req("encode").req("fixed_width").req("4").req("encode_speedup");
        doc.req("exchange").req("methods").req("ALQ").req("4").req("speedup");
        println!("[bench] BENCH_JSON schema OK ({BENCH_SCHEMA})");
    }
}
