//! Bench: the unified exchange engine — serial vs thread-parallel worker
//! lanes on a large gradient (the acceptance measurement for the
//! multi-lane refactor: parallel must beat the seed's serial loop for
//! M ≥ 4). Both schedules are bit-identical by construction (see
//! rust/tests/exchange_parity.rs); this measures only wall clock.
//!
//! Emits the `exchange` section of BENCH_hotloop.json (steps/s serial
//! vs parallel per method × worker count, plus modeled per-hop seconds
//! from the flat topology backend). This binary runs last in the ci.sh
//! bench chain, so when `BENCH_JSON` is set it also validates that the
//! full document carries every section the schema promises.
//!
//! It also measures the `--pipeline` schedules (ISSUE 9): the modeled
//! overlap ledger on a raw sharded backend (deterministic — hidden
//! seconds must be nonzero and wall strictly below off), real TCP
//! wire-path steps/s off vs overlap, and the stale:1 sim schedule.
//! These land in a separate `BENCH_pipeline.json` document when
//! `BENCH_PIPELINE_JSON` is set.

mod bench_util;
use aqsgd::coordinator::leader::run_leader_topo;
use aqsgd::coordinator::{run_worker, WorkerConfig};
use aqsgd::data::Blobs;
use aqsgd::exchange::{
    make_backend, ExchangeConfig, GradientExchange, ParallelMode, PipelineMode, TopologySpec,
};
use aqsgd::model::{Mlp, MlpTask};
use aqsgd::opt::{LrSchedule, UpdateSchedule};
use aqsgd::quant::Method;
use aqsgd::sim::NetworkModel;
use aqsgd::util::json::Json;
use aqsgd::util::Rng;
use bench_util::{
    emit_doc, emit_section, header, load_doc, report, sized, throughput_row, time_per_call,
    window_ms, BENCH_SCHEMA,
};

/// Schema tag for the standalone pipeline perf artifact.
const PIPELINE_SCHEMA: &str = "aqsgd-bench-pipeline/v1";

fn config(method: Method, workers: usize, mode: ParallelMode) -> ExchangeConfig {
    ExchangeConfig {
        method,
        workers,
        bits: aqsgd::exchange::BitsPolicy::Fixed(3),
        bucket: 8192,
        seed: 1,
        network: NetworkModel::paper_testbed(),
        parallel: mode,
        codec: aqsgd::quant::Codec::Huffman,
        quantize_impl: aqsgd::quant::QuantizeImpl::default(),
    }
}

fn engine(method: Method, workers: usize, mode: ParallelMode) -> GradientExchange {
    GradientExchange::new(config(method, workers, mode))
}

fn main() {
    let d = sized(1 << 20, 1 << 14);
    let wms = window_ms(400);

    let mut section = Json::obj();
    section.insert("coords", Json::Num(d as f64));
    let mut methods = Json::obj();

    for method in [Method::QsgdInf, Method::Alq] {
        let mut per_workers = Json::obj();
        for &workers in &[2usize, 4, 8] {
            header(&format!(
                "exchange step: {} @ 3 bits, d = {d}, M = {workers}",
                method.name()
            ));
            let mut rng = Rng::new(7);
            let grads: Vec<Vec<f32>> = (0..workers)
                .map(|_| (0..d).map(|_| (rng.normal() * 0.01) as f32).collect())
                .collect();
            let mut agg = vec![0.0f32; d];

            let mut times = [0.0f64; 2];
            for (i, mode) in [ParallelMode::Serial, ParallelMode::Parallel]
                .into_iter()
                .enumerate()
            {
                let mut eng = engine(method, workers, mode);
                let mut step = 0usize;
                times[i] = time_per_call(
                    || {
                        eng.exchange(step, &grads, &mut agg);
                        step += 1;
                    },
                    wms,
                );
                report(&format!("M={workers} {}", mode.name()), times[i], d * workers);
            }
            println!(
                "    parallel speedup over serial at M={workers}: {:.2}x",
                times[0] / times[1]
            );

            // Sanity: identical bits either way (full parity is tested in
            // rust/tests/exchange_parity.rs).
            let mut a = engine(method, workers, ParallelMode::Serial);
            let mut b = engine(method, workers, ParallelMode::Parallel);
            let bits_a = a.exchange(0, &grads, &mut agg);
            let bits_b = b.exchange(0, &grads, &mut agg);
            assert_eq!(bits_a, bits_b, "schedules must meter identical bits");

            let mut row = Json::obj();
            let mut serial = throughput_row(times[0], d * workers);
            serial.insert("steps_per_sec", Json::Num(1.0 / times[0]));
            let mut parallel = throughput_row(times[1], d * workers);
            parallel.insert("steps_per_sec", Json::Num(1.0 / times[1]));
            row.insert("serial", serial);
            row.insert("parallel", parallel);
            row.insert("speedup", Json::Num(times[0] / times[1]));
            row.insert("bits_per_step", Json::Num(bits_a as f64));
            per_workers.insert(&workers.to_string(), row);
        }
        methods.insert(method.name(), per_workers);
    }
    section.insert("methods", methods);

    // -- modeled per-hop cost on the flat topology backend ---------------
    header("per-hop cost: flat topology backend, M = 4");
    {
        let workers = 4;
        let mut rng = Rng::new(9);
        let grads: Vec<Vec<f32>> = (0..workers)
            .map(|_| (0..d).map(|_| (rng.normal() * 0.01) as f32).collect())
            .collect();
        let mut agg = vec![0.0f32; d];
        let mut backend = make_backend(
            config(Method::Alq, workers, ParallelMode::Serial),
            TopologySpec::Flat,
        );
        let mut step = 0usize;
        let wall = time_per_call(
            || {
                backend.exchange(step, &grads, &mut agg);
                step += 1;
            },
            wms,
        );
        let hops = backend.last_hops().len().max(1);
        let steps = backend.meter().steps.max(1);
        let modeled_per_hop = backend.meter().total_time / steps as f64 / hops as f64;
        println!(
            "flat M={workers}: {hops} hops/step, wall {:.1} µs/hop, modeled net {:.3} ms/hop",
            wall * 1e6 / hops as f64,
            modeled_per_hop * 1e3
        );
        let mut hop = Json::obj();
        hop.insert("topology", Json::Str("flat".into()));
        hop.insert("workers", Json::Num(workers as f64));
        hop.insert("hops_per_step", Json::Num(hops as f64));
        hop.insert("wall_secs_per_hop", Json::Num(wall / hops as f64));
        hop.insert("modeled_secs_per_hop", Json::Num(modeled_per_hop));
        section.insert("per_hop", hop);
    }

    emit_section("exchange", section);

    // -- pipeline schedules (ISSUE 9) -------------------------------------
    let mut pipe_doc = Json::obj();

    header("pipeline: overlap ledger on the sharded backend (modeled)");
    {
        let workers = 4;
        let mut rng = Rng::new(11);
        let grads: Vec<Vec<f32>> = (0..workers)
            .map(|_| (0..d).map(|_| (rng.normal() * 0.01) as f32).collect())
            .collect();
        let mut agg = vec![0.0f32; d];
        let mut measure = |pipeline: PipelineMode| {
            let mut backend = make_backend(
                config(Method::Alq, workers, ParallelMode::Serial),
                TopologySpec::Sharded(3),
            );
            backend.core_mut().set_pipeline(pipeline);
            for step in 0..6 {
                backend.exchange(step, &grads, &mut agg);
            }
            let m = backend.meter();
            (m.total_time, m.hidden_seconds)
        };
        let (comm_off, hidden_off) = measure(PipelineMode::Off);
        let (comm_ov, hidden_ov) = measure(PipelineMode::Overlap);
        // Deterministic contract, not a noisy wall-clock race: overlap
        // must not re-price the modeled wire, must hide nonzero encode
        // seconds, and therefore must report strictly less wall time.
        assert_eq!(
            comm_off.to_bits(),
            comm_ov.to_bits(),
            "overlap re-priced the modeled wire time"
        );
        assert_eq!(hidden_off, 0.0, "off must hide nothing");
        assert!(hidden_ov > 0.0, "overlap hid no encode time");
        println!(
            "sharded:3 M={workers}: modeled comm {:.3} ms, hidden {:.3} ms -> wall {:.3} ms \
             (off {:.3} ms)",
            comm_ov * 1e3,
            hidden_ov * 1e3,
            (comm_ov - hidden_ov) * 1e3,
            comm_off * 1e3,
        );
        let mut sim = Json::obj();
        sim.insert("modeled_comm_secs", Json::Num(comm_ov));
        sim.insert("hidden_secs", Json::Num(hidden_ov));
        sim.insert("wall_secs_overlap", Json::Num(comm_ov - hidden_ov));
        sim.insert("wall_secs_off", Json::Num(comm_off));
        pipe_doc.insert("sim_overlap", sim);
    }

    header("pipeline: TCP wire path, sharded:3, M = 4, off vs overlap");
    {
        let world = 4usize;
        let iters = sized(60, 16);
        let tcp_secs = |pipeline: PipelineMode| -> f64 {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            let t0 = std::time::Instant::now();
            let leader = std::thread::spawn(move || {
                run_leader_topo(listener, world, iters, TopologySpec::Sharded(3)).unwrap()
            });
            let mut handles = Vec::new();
            for w in 0..world {
                let addr = addr.clone();
                handles.push(std::thread::spawn(move || {
                    let cfg = WorkerConfig {
                        addr,
                        worker: w,
                        world,
                        method: Method::Alq,
                        bits: aqsgd::exchange::BitsPolicy::Fixed(3),
                        bucket: 256,
                        iters,
                        lr: LrSchedule::paper_default(0.1, iters),
                        updates: UpdateSchedule::at(vec![3, 15], 30, 15),
                        momentum: 0.9,
                        weight_decay: 1e-4,
                        seed: 42,
                        topology: TopologySpec::Sharded(3),
                        codec: aqsgd::quant::Codec::Huffman,
                        quantize_impl: aqsgd::quant::QuantizeImpl::default(),
                        pipeline,
                        faults: aqsgd::sim::FaultPlan::default(),
                    };
                    let blobs = Blobs::generate(64, 16, 2048, 256, 1.0, 7);
                    let mut task =
                        MlpTask::new(Mlp::new(vec![64, 256, 16]), blobs, 32, world, 7);
                    run_worker(&cfg, &mut task).unwrap()
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            leader.join().unwrap();
            t0.elapsed().as_secs_f64()
        };
        // Min of two runs per mode: whole-run wall over loopback is
        // noisy; the relative order is the measurement.
        let t_off = tcp_secs(PipelineMode::Off).min(tcp_secs(PipelineMode::Off));
        let t_ov = tcp_secs(PipelineMode::Overlap).min(tcp_secs(PipelineMode::Overlap));
        let sps_off = iters as f64 / t_off;
        let sps_ov = iters as f64 / t_ov;
        println!(
            "TCP sharded:3 M={world}: off {sps_off:.1} steps/s, overlap {sps_ov:.1} steps/s \
             ({:.2}x)",
            sps_ov / sps_off
        );
        // The acceptance bar: overlap must not lose throughput on the
        // wire path (the slack absorbs scheduler noise on loopback,
        // where wire time is nearly free and there is little to hide).
        assert!(
            sps_ov >= 0.8 * sps_off,
            "overlap lost wire throughput: {sps_ov:.1} vs {sps_off:.1} steps/s"
        );
        let mut tcp = Json::obj();
        tcp.insert("iters", Json::Num(iters as f64));
        tcp.insert("steps_per_sec_off", Json::Num(sps_off));
        tcp.insert("steps_per_sec_overlap", Json::Num(sps_ov));
        tcp.insert("overlap_speedup", Json::Num(sps_ov / sps_off));
        pipe_doc.insert("tcp", tcp);
    }

    header("pipeline: stale:1 sim schedule (hidden compute ledger)");
    {
        let iters = sized(40, 12);
        let run = |pipeline: PipelineMode| {
            let mut cfg = aqsgd::sim::ClusterConfig::paper_default(Method::Alq, iters);
            cfg.bucket = 256;
            cfg.eval_every = 0;
            cfg.pipeline = pipeline;
            let blobs = Blobs::generate(16, 8, 1600, 200, 1.0, 9);
            let mut task = MlpTask::new(Mlp::new(vec![16, 64, 8]), blobs, 32, cfg.workers, 9);
            aqsgd::sim::Cluster::new(cfg).train(&mut task)
        };
        let off = run(PipelineMode::Off);
        let stale = run(PipelineMode::Stale);
        assert_eq!(off.hidden_time, 0.0, "off must hide nothing");
        assert!(stale.hidden_time > 0.0, "stale:1 hid nothing");
        println!(
            "stale:1 wall {:.3} s vs off {:.3} s (hidden {:.4} s of {:.3} s modeled comm)",
            stale.wall_time(),
            off.wall_time(),
            stale.hidden_time,
            stale.comm_time
        );
        let mut st = Json::obj();
        st.insert("wall_secs_off", Json::Num(off.wall_time()));
        st.insert("wall_secs_stale", Json::Num(stale.wall_time()));
        st.insert("hidden_secs", Json::Num(stale.hidden_time));
        st.insert("comm_secs", Json::Num(stale.comm_time));
        pipe_doc.insert("stale", st);
    }

    emit_doc("BENCH_PIPELINE_JSON", PIPELINE_SCHEMA, pipe_doc);

    // -- final document validation (this binary runs last in ci.sh) ------
    if std::env::var_os("BENCH_JSON").is_some() {
        let doc = load_doc().expect("BENCH_JSON must exist and parse after emission");
        assert_eq!(
            doc.get("schema").and_then(Json::as_str),
            Some(BENCH_SCHEMA),
            "schema tag mismatch"
        );
        for key in ["meta", "quantize", "encode", "exchange"] {
            assert!(
                doc.get(key).is_some(),
                "BENCH_JSON is missing section {key:?} — run the quantize and encode \
                 benches before this one"
            );
        }
        // Spot-check the keys the EXPERIMENTS.md tables read.
        doc.req("quantize").req("widths").req("4").req("speedup");
        doc.req("encode").req("fixed_width").req("4").req("encode_speedup");
        doc.req("exchange").req("methods").req("ALQ").req("4").req("speedup");
        println!("[bench] BENCH_JSON schema OK ({BENCH_SCHEMA})");
    }
}
