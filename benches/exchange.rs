//! Bench: the unified exchange engine — serial vs thread-parallel worker
//! lanes on a large gradient (the acceptance measurement for the
//! multi-lane refactor: parallel must beat the seed's serial loop for
//! M ≥ 4). Both schedules are bit-identical by construction (see
//! rust/tests/exchange_parity.rs); this measures only wall clock.

mod bench_util;
use aqsgd::exchange::{ExchangeConfig, GradientExchange, ParallelMode};
use aqsgd::quant::Method;
use aqsgd::sim::NetworkModel;
use aqsgd::util::Rng;
use bench_util::{header, report, time_per_call};

fn engine(method: Method, workers: usize, mode: ParallelMode) -> GradientExchange {
    GradientExchange::new(ExchangeConfig {
        method,
        workers,
        bits: aqsgd::exchange::BitsPolicy::Fixed(3),
        bucket: 8192,
        seed: 1,
        network: NetworkModel::paper_testbed(),
        parallel: mode,
        codec: aqsgd::quant::Codec::Huffman,
    })
}

fn main() {
    let d = 1 << 20;
    for method in [Method::QsgdInf, Method::Alq] {
        for &workers in &[2usize, 4, 8] {
            header(&format!(
                "exchange step: {} @ 3 bits, d = 2^20, M = {workers}",
                method.name()
            ));
            let mut rng = Rng::new(7);
            let grads: Vec<Vec<f32>> = (0..workers)
                .map(|_| (0..d).map(|_| (rng.normal() * 0.01) as f32).collect())
                .collect();
            let mut agg = vec![0.0f32; d];

            let mut times = [0.0f64; 2];
            for (i, mode) in [ParallelMode::Serial, ParallelMode::Parallel]
                .into_iter()
                .enumerate()
            {
                let mut eng = engine(method, workers, mode);
                let mut step = 0usize;
                times[i] = time_per_call(
                    || {
                        eng.exchange(step, &grads, &mut agg);
                        step += 1;
                    },
                    400,
                );
                report(&format!("M={workers} {}", mode.name()), times[i], d * workers);
            }
            println!(
                "    parallel speedup over serial at M={workers}: {:.2}x",
                times[0] / times[1]
            );

            // Sanity: identical bits either way (full parity is tested in
            // rust/tests/exchange_parity.rs).
            let mut a = engine(method, workers, ParallelMode::Serial);
            let mut b = engine(method, workers, ParallelMode::Parallel);
            let bits_a = a.exchange(0, &grads, &mut agg);
            let bits_b = b.exchange(0, &grads, &mut agg);
            assert_eq!(bits_a, bits_b, "schedules must meter identical bits");
        }
    }
}
