//! Bench: the executable exchange topologies — wall-clock step time,
//! total metered bits, and modeled α-β network seconds across
//! M ∈ {4, 8, 16} workers for flat, sharded, tree, and ring schedules
//! (the EXPERIMENTS.md topology scaling table).
//!
//! What to look for:
//! * sharded meters exactly the flat bit total (routing, not payload);
//! * tree's top-level hop carries G frames instead of M — its modeled
//!   network time flattens as M grows;
//! * ring's modeled time per worker stays near-constant in M while its
//!   total injected bits grow ~2(M−1)/M·flat.

mod bench_util;
use aqsgd::exchange::{make_backend, ExchangeConfig, ParallelMode, TopologySpec};
use aqsgd::quant::{Codec, Method};
use aqsgd::sim::{NetworkModel, Topology};
use aqsgd::util::Rng;
use bench_util::{header, time_per_call};

fn config(workers: usize, topo: TopologySpec) -> ExchangeConfig {
    // The flat engine charges the analytical closed form of
    // `network.topology`; pin it to the flat all-to-all fabric so the
    // flat row is comparable to the per-link-metered schedules (the
    // paper_testbed default is the ring closed form). The topology
    // backends meter per link and ignore this field.
    let network = match topo {
        TopologySpec::Flat => NetworkModel {
            topology: Topology::FlatAllToAll,
            ..NetworkModel::paper_testbed()
        },
        _ => NetworkModel::paper_testbed(),
    };
    ExchangeConfig {
        method: Method::Alq,
        workers,
        bits: 3,
        bucket: 8192,
        seed: 1,
        network,
        parallel: ParallelMode::Serial,
        codec: Codec::Huffman,
    }
}

fn main() {
    let d = 1 << 18;
    println!("topology scaling: ALQ @ 3 bits, d = 2^18, paper testbed network");
    for &workers in &[4usize, 8, 16] {
        header(&format!("M = {workers}"));
        let mut rng = Rng::new(7);
        let grads: Vec<Vec<f32>> = (0..workers)
            .map(|_| (0..d).map(|_| (rng.normal() * 0.01) as f32).collect())
            .collect();
        let mut agg = vec![0.0f32; d];
        let topologies = [
            TopologySpec::Flat,
            TopologySpec::Sharded(4),
            TopologySpec::Tree(workers / 4),
            TopologySpec::Ring,
        ];
        println!(
            "{:<12} {:>14} {:>16} {:>16} {:>8}",
            "topology", "step wall (µs)", "bits/step", "net model (ms)", "hops"
        );
        for topo in topologies {
            let mut backend = make_backend(config(workers, topo), topo);
            let mut step = 0usize;
            let wall = time_per_call(
                || {
                    backend.exchange(step, &grads, &mut agg);
                    step += 1;
                },
                300,
            );
            let hops = backend.last_hops().len();
            let bits_per_step = backend.meter().total_bits / backend.meter().steps.max(1);
            let net_ms =
                backend.meter().total_time / backend.meter().steps.max(1) as f64 * 1e3;
            println!(
                "{:<12} {:>14.1} {:>16} {:>16.3} {:>8}",
                topo.name(),
                wall * 1e6,
                bits_per_step,
                net_ms,
                hops
            );
        }
    }
    println!("\n(regenerate the EXPERIMENTS.md table from this output)");
}
