//! Bench: the executable exchange topologies — serial vs parallel
//! wall-clock step time, total metered bits, and modeled α-β network
//! seconds across M ∈ {4, 8, 16} workers for flat, sharded, tree, and
//! ring schedules (the EXPERIMENTS.md topology + parallel scaling
//! tables).
//!
//! What to look for:
//! * sharded meters exactly the flat bit total (routing, not payload);
//! * `--parallel on` (the "par µs" column) beats "ser µs" for flat,
//!   sharded, and tree — the member stage and the shard/group leader
//!   lanes fan out across threads with bit-identical results (the
//!   bits/step column is asserted equal across modes);
//! * ring's two columns match: its 2(M−1)-stage schedule is a serial
//!   dependency chain, so `--parallel` is a documented no-op there;
//! * tree's top-level hop carries G frames instead of M — its modeled
//!   network time flattens as M grows;
//! * ring's modeled time per worker stays near-constant in M while its
//!   total injected bits grow ~2(M−1)/M·flat.

mod bench_util;
use aqsgd::exchange::{make_backend, BitsPolicy, ExchangeConfig, ParallelMode, TopologySpec};
use aqsgd::quant::{Codec, Method};
use aqsgd::sim::{NetworkModel, Topology};
use aqsgd::util::Rng;
use bench_util::{header, time_per_call};

fn config(workers: usize, topo: TopologySpec, parallel: ParallelMode) -> ExchangeConfig {
    // The flat engine charges the analytical closed form of
    // `network.topology`; pin it to the flat all-to-all fabric so the
    // flat row is comparable to the per-link-metered schedules (the
    // paper_testbed default is the ring closed form). The topology
    // backends meter per link and ignore this field.
    let network = match topo {
        TopologySpec::Flat => NetworkModel {
            topology: Topology::FlatAllToAll,
            ..NetworkModel::paper_testbed()
        },
        _ => NetworkModel::paper_testbed(),
    };
    ExchangeConfig {
        method: Method::Alq,
        workers,
        bits: BitsPolicy::Fixed(3),
        bucket: 8192,
        seed: 1,
        network,
        parallel,
        codec: Codec::Huffman,
        quantize_impl: aqsgd::quant::QuantizeImpl::default(),
    }
}

/// Measure one (topology, mode) cell: seconds per step plus the meter
/// aggregates after the timed run.
fn run_cell(
    workers: usize,
    topo: TopologySpec,
    mode: ParallelMode,
    grads: &[Vec<f32>],
    agg: &mut [f32],
) -> (f64, u64, f64, usize) {
    let mut backend = make_backend(config(workers, topo, mode), topo);
    let mut step = 0usize;
    let wall = time_per_call(
        || {
            backend.exchange(step, grads, agg);
            step += 1;
        },
        300,
    );
    let hops = backend.last_hops().len();
    let steps = backend.meter().steps.max(1);
    let bits_per_step = backend.meter().total_bits / steps;
    let net_ms = backend.meter().total_time / steps as f64 * 1e3;
    (wall, bits_per_step, net_ms, hops)
}

/// Bits-policy savings: total metered bits (and mean width) each
/// `--bits-policy` produces on the same gradients — the meter charges
/// the *actual* per-step width, so the savings column is measured, not
/// nominal. Verifies per backend that the hop-sum invariant holds while
/// the width moves.
fn bits_policy_section(workers: usize, grads: &[Vec<f32>], agg: &mut [f32]) {
    header(&format!("bits-policy savings (M = {workers}, 24 steps)"));
    let steps = 24usize;
    let policies = [
        BitsPolicy::Fixed(3),
        BitsPolicy::parse("schedule:4@0,3@8,2@16").unwrap(),
        BitsPolicy::parse("variance:2-4").unwrap(),
    ];
    println!(
        "{:<12} {:<22} {:>14} {:>12} {:>10}",
        "topology", "policy", "total bits", "mean width", "vs fixed"
    );
    for topo in [TopologySpec::Flat, TopologySpec::Tree(2)] {
        let mut fixed_total = 0u64;
        for policy in &policies {
            let mut cfg = config(workers, topo, ParallelMode::Serial);
            cfg.bits = policy.clone();
            let mut backend = make_backend(cfg, topo);
            let mut total = 0u64;
            let mut width_sum = 0u64;
            for step in 0..steps {
                if step == 8 {
                    backend.adapt(grads);
                }
                let bits = backend.exchange(step, grads, agg);
                let hop_sum: u64 = backend.last_hops().iter().map(|h| h.bits).sum();
                assert_eq!(hop_sum, bits, "{}: hop-sum under {}", topo.name(), policy);
                total += bits;
                width_sum += backend.step_width() as u64;
            }
            if policy.is_fixed() {
                fixed_total = total;
            }
            println!(
                "{:<12} {:<22} {:>14} {:>12.2} {:>9.1}%",
                topo.name(),
                policy.name(),
                total,
                width_sum as f64 / steps as f64,
                100.0 * total as f64 / fixed_total.max(1) as f64
            );
        }
    }
}

fn main() {
    let d = 1 << 18;
    println!(
        "topology scaling, serial vs parallel lanes: ALQ @ 3 bits, d = 2^18, \
         paper testbed network"
    );
    for &workers in &[4usize, 8, 16] {
        header(&format!("M = {workers}"));
        let mut rng = Rng::new(7);
        let grads: Vec<Vec<f32>> = (0..workers)
            .map(|_| (0..d).map(|_| (rng.normal() * 0.01) as f32).collect())
            .collect();
        let mut agg = vec![0.0f32; d];
        let topologies = [
            TopologySpec::Flat,
            TopologySpec::Sharded(4),
            TopologySpec::Tree(workers / 4),
            TopologySpec::Ring,
        ];
        println!(
            "{:<12} {:>12} {:>12} {:>8} {:>16} {:>14} {:>6}",
            "topology", "ser µs", "par µs", "speedup", "bits/step", "net model (ms)", "hops"
        );
        for topo in topologies {
            // The BackendCore contract: lane scheduling never changes a
            // metered bit. Verify on fresh backends over a fixed number
            // of steps (the timed runs below execute different step
            // counts, so their totals are not comparable).
            {
                let mut ser = make_backend(config(workers, topo, ParallelMode::Serial), topo);
                let mut par = make_backend(config(workers, topo, ParallelMode::Parallel), topo);
                for step in 0..4 {
                    let bs = ser.exchange(step, &grads, &mut agg);
                    let bp = par.exchange(step, &grads, &mut agg);
                    assert_eq!(
                        bs,
                        bp,
                        "{}: serial and parallel bits diverged at step {step}",
                        topo.name()
                    );
                }
            }
            let (ser_wall, ser_bits, net_ms, hops) =
                run_cell(workers, topo, ParallelMode::Serial, &grads, &mut agg);
            let (par_wall, _, _, _) =
                run_cell(workers, topo, ParallelMode::Parallel, &grads, &mut agg);
            println!(
                "{:<12} {:>12.1} {:>12.1} {:>7.2}x {:>16} {:>14.3} {:>6}",
                topo.name(),
                ser_wall * 1e6,
                par_wall * 1e6,
                ser_wall / par_wall,
                ser_bits,
                net_ms,
                hops
            );
        }
    }
    let mut rng = Rng::new(11);
    let grads: Vec<Vec<f32>> = (0..4)
        .map(|_| (0..d).map(|_| (rng.normal() * 0.01) as f32).collect())
        .collect();
    let mut agg = vec![0.0f32; d];
    bits_policy_section(4, &grads, &mut agg);

    println!("\n(regenerate the EXPERIMENTS.md tables from this output)");
}
