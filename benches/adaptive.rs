//! Bench: adaptive level-update cost (Table 7's source) — estimator fit,
//! ALQ coordinate descent, safeguarded GD, AMQ multiplier descent, and
//! the Prop. 6 codebook rebuild.

mod bench_util;
use aqsgd::adaptive::{alq, amq, gd, objective, Estimator};
use aqsgd::quant::{Levels, NormType};
use aqsgd::stats::Mixture;
use aqsgd::util::Rng;
use bench_util::{header, report, time_per_call};

fn mixture(components: usize, seed: u64) -> Mixture {
    let mut rng = Rng::new(seed);
    let n = components * 8192;
    let grad: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.01) as f32).collect();
    let mut est = Estimator::new(8192, NormType::L2, components);
    est.observe(&grad);
    est.fit(true, &mut rng).unwrap()
}

fn main() {
    let n = 1 << 20;
    let mut rng = Rng::new(3);
    let grad: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.01) as f32).collect();

    header("estimator: per-bucket sufficient statistics, 1M coords");
    for bucket in [64usize, 8192] {
        let mut est = Estimator::new(bucket, NormType::L2, 350);
        let t = time_per_call(
            || {
                est.clear();
                est.observe(&grad);
            },
            300,
        );
        report(&format!("observe bucket={bucket}"), t, n);
    }

    // Paper scales: 20 components (CIFAR) and 350 (ImageNet).
    for comps in [20usize, 350] {
        let mix = mixture(comps, 4);
        header(&format!("level optimizers on a {comps}-component mixture"));
        for bits in [3u32, 8] {
            let k = Levels::mags_for_bits(bits);
            let init = Levels::exponential(k, 0.5);
            let t = time_per_call(
                || {
                    std::hint::black_box(alq::optimize(&mix, &init, alq::AlqOptions::default()));
                },
                200,
            );
            report(&format!("ALQ CD bits={bits}"), t, 1);
        }
        let init = Levels::exponential(4, 0.5);
        let t = time_per_call(
            || {
                std::hint::black_box(gd::optimize(
                    &mix,
                    &init,
                    gd::GdOptions { steps: 50, ..Default::default() },
                ));
            },
            200,
        );
        report("ALQ-G 50 GD steps bits=3", t, 1);
        let t = time_per_call(
            || {
                std::hint::black_box(amq::optimize(&mix, 4, 0.5, amq::AmqOptions::default()));
            },
            200,
        );
        report("AMQ multiplier descent bits=3", t, 1);
        let levels = Levels::exponential(4, 0.5);
        let t = time_per_call(
            || {
                std::hint::black_box(objective::symbol_probs(&mix, &levels));
            },
            200,
        );
        report("Prop.6 symbol probabilities", t, 1);
    }
}
