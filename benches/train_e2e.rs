//! Bench: end-to-end simulated data-parallel training throughput per
//! method (steps/s on the ResNet-32 stand-in), plus one PJRT-backed HLO
//! step if artifacts are present. The quantized/full-precision deltas
//! here isolate the coordinator's own overhead (L3 should not be the
//! bottleneck — DESIGN.md §Perf).

mod bench_util;
use aqsgd::exp::common::ModelSpec;
use aqsgd::quant::Method;
use aqsgd::sim::Cluster;
use bench_util::header;
use std::time::Instant;

fn main() {
    let spec = ModelSpec::resnet32_standin();
    let iters = 150;
    header(&format!(
        "simulated cluster: {} ({} params), 4 workers, {iters} steps",
        spec.name,
        spec.param_count()
    ));
    println!(
        "{:<12} {:>9} {:>12} {:>14} {:>12}",
        "method", "steps/s", "ms/step", "codec ms/step", "bits/step"
    );
    for method in [
        Method::SuperSgd,
        Method::QsgdInf,
        Method::Trn,
        Method::NuqSgd,
        Method::Alq,
        Method::Amq,
    ] {
        let mut cfg = aqsgd::exp::common::cluster_config(method, &spec, iters, 4, 3, spec.bucket, 1);
        cfg.eval_every = 0;
        let mut task = spec.task(4, 3);
        let t0 = Instant::now();
        let rec = Cluster::new(cfg).train(&mut task);
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<12} {:>9.1} {:>12.2} {:>14.3} {:>12.0}",
            method.name(),
            iters as f64 / dt,
            dt * 1e3 / iters as f64,
            rec.codec_seconds * 1e3 / iters as f64,
            rec.comm_bits as f64 / iters as f64
        );
    }

    // HLO path (requires `make artifacts`).
    if let Ok(manifest) = aqsgd::runtime::Manifest::load_default() {
        if let Ok(rt) = aqsgd::runtime::Runtime::cpu() {
            use aqsgd::model::TrainTask;
            header("PJRT HLO step (mlp_small train fwd+bwd)");
            if let Ok(mut task) =
                aqsgd::model::HloMlpTask::load(&rt, &manifest, "mlp_small", 4, 3)
            {
                let params = task.init_params(1);
                let mut g = vec![0.0f32; task.param_count()];
                task.grad(&params, 0, 0, &mut g); // compile+warm
                let t0 = Instant::now();
                let reps = 20;
                for s in 0..reps {
                    task.grad(&params, 0, s, &mut g);
                }
                println!(
                    "mlp_small ({} params): {:.2} ms/grad-step",
                    task.param_count(),
                    t0.elapsed().as_secs_f64() * 1e3 / reps as f64
                );
            }
        }
    }
}
