//! Bench: the quantization hot path (L3 native + the HLO kernel).
//! Source for the codec component of Tables 5–6, and for the
//! `quantize` section of BENCH_hotloop.json (scalar reference vs the
//! vectorized fast path, coords/s per width).
//!
//! The two host paths are bit-identical by contract (pinned by
//! rust/src/quant/quantizer.rs tests and the lane/cluster parity
//! tests); this binary measures only throughput.

mod bench_util;
use aqsgd::quant::{Levels, NormType, QuantScratch, Quantizer};
use aqsgd::util::json::Json;
use aqsgd::util::Rng;
use bench_util::{emit_section, header, report, sized, throughput_row, time_per_call, window_ms};

fn main() {
    let n = sized(1 << 20, 1 << 16);
    let mut rng = Rng::new(1);
    let v: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.01) as f32).collect();
    let coords = if n >= 1 << 20 {
        format!("{}M", n >> 20)
    } else {
        format!("{}k", n >> 10)
    };
    let wms = window_ms(300);

    let mut section = Json::obj();
    section.insert("coords", Json::Num(n as f64));
    section.insert("bucket", Json::Num(8192.0));
    let mut widths = Json::obj();

    header(&format!(
        "quantize scalar vs fast (stochastic rounding + norms), {coords} coords, bucket 8192"
    ));
    for bits in [2u32, 3, 4, 8] {
        let q = Quantizer::new(
            Levels::exponential(Levels::mags_for_bits(bits), 0.5),
            NormType::L2,
            8192,
        );
        let mut out = q.quantize(&v, &mut rng);
        let mut scratch = QuantScratch::default();
        let t_scalar = time_per_call(|| q.quantize_into_scalar(&v, &mut rng, &mut out), wms);
        let t_fast = time_per_call(
            || q.quantize_into_with(&v, &mut rng, &mut scratch, &mut out),
            wms,
        );
        report(&format!("scalar bits={bits}"), t_scalar, n);
        report(&format!("fast   bits={bits}"), t_fast, n);
        println!("    fast speedup at bits={bits}: {:.2}x", t_scalar / t_fast);

        let mut w = Json::obj();
        w.insert("scalar", throughput_row(t_scalar, n));
        w.insert("fast", throughput_row(t_fast, n));
        w.insert("speedup", Json::Num(t_scalar / t_fast));
        widths.insert(&bits.to_string(), w);
    }
    section.insert("widths", widths);

    header(&format!("quantize per bucket size, {coords} coords"));
    for bits in [3u32, 8] {
        for bucket in [64usize, 8192] {
            let q = Quantizer::new(
                Levels::exponential(Levels::mags_for_bits(bits), 0.5),
                NormType::L2,
                bucket,
            );
            let mut out = q.quantize(&v, &mut rng);
            let t = time_per_call(|| q.quantize_into(&v, &mut rng, &mut out), wms);
            report(&format!("quantize bits={bits} bucket={bucket}"), t, n);
        }
    }

    header(&format!("dequantize, {coords} coords"));
    for bits in [3u32, 8] {
        let q = Quantizer::new(
            Levels::exponential(Levels::mags_for_bits(bits), 0.5),
            NormType::L2,
            8192,
        );
        let g = q.quantize(&v, &mut rng);
        let mut out = vec![0.0f32; n];
        let t = time_per_call(|| q.dequantize(&g, &mut out), wms);
        report(&format!("dequantize bits={bits} bucket=8192"), t, n);
    }

    header(&format!("exact_variance (Eq. 1-2 closed form), {coords} coords"));
    let q = Quantizer::new(Levels::exponential(4, 0.5), NormType::L2, 8192);
    let t = time_per_call(
        || {
            std::hint::black_box(q.exact_variance(&v));
        },
        wms,
    );
    report("exact_variance bits=3 bucket=8192", t, n);

    header(&format!("Linf vs L2 norms, {coords} coords"));
    for nt in [NormType::L2, NormType::Linf] {
        let q = Quantizer::new(Levels::uniform(4), nt, 8192);
        let mut out = q.quantize(&v, &mut rng);
        let t = time_per_call(|| q.quantize_into(&v, &mut rng, &mut out), wms);
        report(&format!("quantize {nt:?} bucket=8192"), t, n);
    }

    emit_section("quantize", section);
}
