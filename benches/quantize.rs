//! Bench: the quantization hot path (L3 native + the HLO kernel).
//! Source for the codec component of Tables 5–6.

mod bench_util;
use aqsgd::quant::{Levels, NormType, Quantizer};
use aqsgd::util::Rng;
use bench_util::{header, report, time_per_call};

fn main() {
    let n = 1 << 20;
    let mut rng = Rng::new(1);
    let v: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.01) as f32).collect();

    header("quantize (stochastic rounding + norms), 1M coords");
    for bits in [2u32, 3, 4, 8] {
        for bucket in [64usize, 8192] {
            let q = Quantizer::new(
                Levels::exponential(Levels::mags_for_bits(bits), 0.5),
                NormType::L2,
                bucket,
            );
            let mut out = q.quantize(&v, &mut rng);
            let t = time_per_call(|| q.quantize_into(&v, &mut rng, &mut out), 300);
            report(&format!("quantize bits={bits} bucket={bucket}"), t, n);
        }
    }

    header("dequantize, 1M coords");
    for bits in [3u32, 8] {
        let q = Quantizer::new(
            Levels::exponential(Levels::mags_for_bits(bits), 0.5),
            NormType::L2,
            8192,
        );
        let g = q.quantize(&v, &mut rng);
        let mut out = vec![0.0f32; n];
        let t = time_per_call(|| q.dequantize(&g, &mut out), 300);
        report(&format!("dequantize bits={bits} bucket=8192"), t, n);
    }

    header("exact_variance (Eq. 1-2 closed form), 1M coords");
    let q = Quantizer::new(Levels::exponential(4, 0.5), NormType::L2, 8192);
    let t = time_per_call(
        || {
            std::hint::black_box(q.exact_variance(&v));
        },
        300,
    );
    report("exact_variance bits=3 bucket=8192", t, n);

    header("Linf vs L2 norms, 1M coords");
    for nt in [NormType::L2, NormType::Linf] {
        let q = Quantizer::new(Levels::uniform(4), nt, 8192);
        let mut out = q.quantize(&v, &mut rng);
        let t = time_per_call(|| q.quantize_into(&v, &mut rng, &mut out), 300);
        report(&format!("quantize {nt:?} bucket=8192"), t, n);
    }
}
