//! Bench: full per-step codec pipeline at the paper's model sizes —
//! quantize → encode → decode → dequantize for ResNet18/ResNet50-sized
//! gradients (the measured half of Tables 5–6; the α-β network model is
//! applied in `aqsgd exp timing`).

mod bench_util;
use aqsgd::quant::{decode, encode, symbol_counts, HuffmanBook, Levels, NormType, Quantizer};
use aqsgd::util::Rng;
use bench_util::{header, report, time_per_call};

fn main() {
    // Use 2^22 coords (≈ 4.2M) as a proxy chunk; costs are linear in d.
    let n = 1 << 22;
    let mut rng = Rng::new(5);
    let v: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.01) as f32).collect();

    for bits in [2u32, 3, 4, 6, 8] {
        for bucket in [64usize, 1024, 8192, 16384] {
            let levels = Levels::exponential(Levels::mags_for_bits(bits), 0.5);
            let quant = Quantizer::new(levels.clone(), NormType::L2, bucket);
            let g0 = quant.quantize(&v, &mut rng);
            let book = HuffmanBook::from_weights(
                &symbol_counts(&g0, &levels)
                    .iter()
                    .map(|c| c + 1.0)
                    .collect::<Vec<_>>(),
            );
            let mut out = vec![0.0f32; n];
            let mut qbuf = g0.clone();
            let t = time_per_call(
                || {
                    quant.quantize_into(&v, &mut rng, &mut qbuf);
                    let e = encode(&qbuf, &levels, &book);
                    let d = decode(&e, &levels, &book);
                    quant.dequantize(&d, &mut out);
                },
                400,
            );
            header(&format!("full codec pipeline bits={bits} bucket={bucket}"));
            report("quantize+encode+decode+dequantize", t, n);
            // Extrapolate to the paper's models (linear in d).
            for (model, d_model) in [("ResNet18", 11_690_000usize), ("ResNet50", 25_560_000)] {
                println!(
                    "  extrapolated {model} ({d_model} params): {:.1} ms/worker/step",
                    t * 1e3 * d_model as f64 / n as f64
                );
            }
        }
    }
}
