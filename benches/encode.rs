//! Bench: the entropy codec (Appendix D) — Huffman encode/decode and
//! the achieved bits/coordinate vs the Theorem 3 bound.

mod bench_util;
use aqsgd::quant::{decode, encode, encode_into, symbol_counts, theory, HuffmanBook, Levels, NormType, Quantizer};
use aqsgd::quant::bitio::BitWriter;
use aqsgd::util::Rng;
use bench_util::{header, report, time_per_call};

fn main() {
    let n = 1 << 20;
    let mut rng = Rng::new(2);
    let v: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.01) as f32).collect();

    for bits in [2u32, 3, 4, 8] {
        let levels = Levels::exponential(Levels::mags_for_bits(bits), 0.5);
        let quant = Quantizer::new(levels.clone(), NormType::L2, 8192);
        let g = quant.quantize(&v, &mut rng);
        let counts = symbol_counts(&g, &levels);
        let book = HuffmanBook::from_weights(
            &counts.iter().map(|c| c + 1.0).collect::<Vec<_>>(),
        );

        header(&format!("codec at bits={bits}, bucket=8192, 1M coords"));
        let mut w = BitWriter::new();
        let t_enc = time_per_call(
            || {
                w.clear();
                std::hint::black_box(encode_into(&g, &levels, &book, &mut w));
            },
            300,
        );
        report("huffman encode", t_enc, n);

        let e = encode(&g, &levels, &book);
        let t_dec = time_per_call(
            || {
                std::hint::black_box(decode(&e, &levels, &book));
            },
            300,
        );
        report("huffman decode", t_dec, n);

        let total: f64 = counts.iter().sum();
        let probs: Vec<f64> = counts.iter().map(|c| c / total).collect();
        let h = theory::entropy_bits(&probs);
        let achieved = e.bits as f64 / n as f64;
        let bound = theory::code_length_bound(&levels, n, 2.0, &probs) / n as f64;
        println!(
            "  bits/coord: achieved {achieved:.3}, symbol entropy {h:.3}, Thm-3 bound {bound:.3} \
             (naive {} bits)",
            bits
        );
    }
}
