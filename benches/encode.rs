//! Bench: the entropy codec (Appendix D) — Huffman encode/decode and
//! the achieved bits/coordinate vs the Theorem 3 bound, plus the
//! byte-aligned pow-2 fast path vs the bit-cursor reference:
//! * raw `pack_pow2` u64-lane packing vs per-symbol `push_bits_lsb`
//!   for every supported width {1, 2, 3, 4, 8};
//! * full fixed-width encode/decode (`encode_buckets_into`, which
//!   auto-detects the pow-2 book) vs the forced cursor path.
//!
//! Emits the `encode` section of BENCH_hotloop.json and asserts the
//! PR's acceptance bar: the fast path must encode at ≥ 2× the cursor
//! throughput on the 4-bit fixed-width config. Both paths are pinned
//! bit-identical by rust/src/quant/encode.rs tests; this binary only
//! measures (and re-checks equality on one frame as a cheap sanity).

mod bench_util;
use aqsgd::quant::bitio::BitWriter;
use aqsgd::quant::{
    decode, decode_view_into, decode_view_into_cursor, encode, encode_buckets_into,
    encode_buckets_into_cursor, encode_into, fixed_width, symbol_counts, theory, HuffmanBook,
    Levels, NormType, Quantizer,
};
use aqsgd::util::json::Json;
use aqsgd::util::Rng;
use bench_util::{emit_section, header, report, sized, throughput_row, time_per_call, window_ms};

/// The (levels, book) pairs that admit each fixed width. Width 1 has no
/// level family (a 1-bit record cannot carry magnitude + sign), so the
/// full-encode sweep covers {2, 3, 4, 8} and the raw packer sweep below
/// covers {1, 2, 3, 4, 8} — width 3 is the 21-records-per-63-bit-lane
/// odd case added by the pipeline PR.
fn fixed_width_configs() -> Vec<(u32, Levels, HuffmanBook)> {
    vec![
        (2, Levels::amq(2, 0.5), HuffmanBook::from_weights(&[1.0; 2])),
        (3, Levels::amq(4, 0.5), HuffmanBook::from_weights(&[1.0; 4])),
        (
            4,
            Levels::exponential(8, 0.5),
            HuffmanBook::from_lengths(vec![4, 3, 3, 3, 3, 3, 3, 3]),
        ),
        (8, Levels::exponential(128, 0.5), {
            let mut lens = vec![7u32; 128];
            lens[0] = 8;
            HuffmanBook::from_lengths(lens)
        }),
    ]
}

fn main() {
    let n = sized(1 << 20, 1 << 16);
    let wms = window_ms(300);
    let mut rng = Rng::new(2);
    let v: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.01) as f32).collect();

    let mut section = Json::obj();
    section.insert("coords", Json::Num(n as f64));

    // -- raw packer: u64 lanes vs per-symbol cursor pushes ---------------
    header(&format!("pack_pow2 vs push_bits_lsb cursor, {n} symbols"));
    let mut packs = Json::obj();
    for width in [1u32, 2, 3, 4, 8] {
        let mask = (1u64 << width) - 1;
        let syms: Vec<u64> = (0..n).map(|_| rng.next_u64() & mask).collect();
        let mut w = BitWriter::new();
        let t_pack = time_per_call(
            || {
                w.clear();
                w.pack_pow2(width, &syms);
                std::hint::black_box(w.bits_written());
            },
            wms,
        );
        let t_cursor = time_per_call(
            || {
                w.clear();
                for &s in &syms {
                    w.push_bits_lsb(s, width);
                }
                std::hint::black_box(w.bits_written());
            },
            wms,
        );
        report(&format!("pack_pow2 width={width}"), t_pack, n);
        report(&format!("cursor    width={width}"), t_cursor, n);
        println!(
            "    pack speedup at width={width}: {:.2}x",
            t_cursor / t_pack
        );
        let mut row = Json::obj();
        row.insert("pack", throughput_row(t_pack, n));
        row.insert("cursor", throughput_row(t_cursor, n));
        row.insert("speedup", Json::Num(t_cursor / t_pack));
        packs.insert(&width.to_string(), row);
    }
    section.insert("pack_pow2", packs);

    // -- full fixed-width encode/decode: fast vs forced cursor -----------
    let mut fixed = Json::obj();
    for (width, levels, book) in fixed_width_configs() {
        assert_eq!(
            fixed_width(&levels, &book),
            Some(width),
            "bench config must admit the pow-2 fast path"
        );
        let quant = Quantizer::new(levels.clone(), NormType::L2, 8192);
        let g = quant.quantize(&v, &mut rng);
        let nb = g.norms.len();

        header(&format!(
            "fixed-width codec: fast vs cursor, width={width}, {n} coords"
        ));
        let mut w = BitWriter::new();
        let t_fast = time_per_call(
            || {
                w.clear();
                std::hint::black_box(encode_buckets_into(&g, &levels, &book, 0..nb, true, &mut w));
            },
            wms,
        );
        let t_cursor = time_per_call(
            || {
                w.clear();
                std::hint::black_box(encode_buckets_into_cursor(
                    &g, &levels, &book, 0..nb, true, &mut w,
                ));
            },
            wms,
        );
        report(&format!("fast encode   width={width}"), t_fast, n);
        report(&format!("cursor encode width={width}"), t_cursor, n);
        let speedup = t_cursor / t_fast;
        println!("    fast encode speedup at width={width}: {speedup:.2}x");

        // One frame through both paths: equal bits, equal symbols.
        let e = encode(&g, &levels, &book);
        let mut via_fast = g.clone();
        let mut via_cursor = g.clone();
        decode_view_into(e.view(), &levels, &book, &mut via_fast);
        decode_view_into_cursor(e.view(), &levels, &book, &mut via_cursor);
        assert_eq!(via_fast, via_cursor, "width={width}: decode paths diverged");
        assert_eq!(via_fast, g, "width={width}: roundtrip corrupted symbols");

        let t_dec_fast = time_per_call(
            || {
                decode_view_into(e.view(), &levels, &book, &mut via_fast);
            },
            wms,
        );
        let t_dec_cursor = time_per_call(
            || {
                decode_view_into_cursor(e.view(), &levels, &book, &mut via_cursor);
            },
            wms,
        );
        report(&format!("fast decode   width={width}"), t_dec_fast, n);
        report(&format!("cursor decode width={width}"), t_dec_cursor, n);

        let mut row = Json::obj();
        row.insert("encode_fast", throughput_row(t_fast, n));
        row.insert("encode_cursor", throughput_row(t_cursor, n));
        row.insert("decode_fast", throughput_row(t_dec_fast, n));
        row.insert("decode_cursor", throughput_row(t_dec_cursor, n));
        row.insert("encode_speedup", Json::Num(speedup));
        row.insert("bits_per_sec_fast", Json::Num(e.bits as f64 / t_fast));
        fixed.insert(&width.to_string(), row);

        // Acceptance bar (ISSUE 6): the byte-aligned path must encode at
        // ≥ 2x cursor throughput on the 4-bit fixed-width config.
        if width == 4 {
            assert!(
                speedup >= 2.0,
                "4-bit fixed-width fast encode is only {speedup:.2}x the cursor path \
                 (acceptance bar: >= 2x)"
            );
        }
    }
    section.insert("fixed_width", fixed);

    // -- entropy codec sweep (Appendix D tables) -------------------------
    let mut huffman = Json::obj();
    for bits in [2u32, 3, 4, 8] {
        let levels = Levels::exponential(Levels::mags_for_bits(bits), 0.5);
        let quant = Quantizer::new(levels.clone(), NormType::L2, 8192);
        let g = quant.quantize(&v, &mut rng);
        let counts = symbol_counts(&g, &levels);
        let book =
            HuffmanBook::from_weights(&counts.iter().map(|c| c + 1.0).collect::<Vec<_>>());

        header(&format!("codec at bits={bits}, bucket=8192, {n} coords"));
        let mut w = BitWriter::new();
        let t_enc = time_per_call(
            || {
                w.clear();
                std::hint::black_box(encode_into(&g, &levels, &book, &mut w));
            },
            wms,
        );
        report("huffman encode", t_enc, n);

        let e = encode(&g, &levels, &book);
        let t_dec = time_per_call(
            || {
                std::hint::black_box(decode(&e, &levels, &book));
            },
            wms,
        );
        report("huffman decode", t_dec, n);

        let total: f64 = counts.iter().sum();
        let probs: Vec<f64> = counts.iter().map(|c| c / total).collect();
        let h = theory::entropy_bits(&probs);
        let achieved = e.bits as f64 / n as f64;
        let bound = theory::code_length_bound(&levels, n, 2.0, &probs) / n as f64;
        println!(
            "  bits/coord: achieved {achieved:.3}, symbol entropy {h:.3}, Thm-3 bound {bound:.3} \
             (naive {} bits)",
            bits
        );

        let mut row = Json::obj();
        row.insert("encode", throughput_row(t_enc, n));
        row.insert("decode", throughput_row(t_dec, n));
        row.insert("bits_per_coord", Json::Num(achieved));
        row.insert("bits_per_sec", Json::Num(e.bits as f64 / t_enc));
        huffman.insert(&bits.to_string(), row);
    }
    section.insert("huffman", huffman);

    emit_section("encode", section);
}
