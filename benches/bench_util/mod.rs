//! Tiny shared bench harness (criterion is not in the offline vendor
//! set): warmup + timed reps, median-of-runs, ns/item reporting.

use std::time::Instant;

/// Run `f` repeatedly for ~`target_ms` and return seconds per call.
pub fn time_per_call<F: FnMut()>(mut f: F, target_ms: u64) -> f64 {
    // Warmup.
    f();
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let reps = ((target_ms as f64 / 1e3 / once).ceil() as usize).clamp(3, 10_000);
    let mut samples = Vec::with_capacity(5);
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..reps {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / reps as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

pub fn report(name: &str, secs_per_call: f64, items: usize) {
    println!(
        "{name:<44} {:>10.3} µs/call {:>9.2} ns/item {:>10.1} Mitem/s",
        secs_per_call * 1e6,
        secs_per_call * 1e9 / items as f64,
        items as f64 / secs_per_call / 1e6
    );
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}
