//! Tiny shared bench harness (criterion is not in the offline vendor
//! set): warmup + timed reps, median-of-runs, ns/item reporting, and the
//! `BENCH_*.json` perf-trajectory emitter.
//!
//! Environment knobs (all optional — unset means interactive full run):
//! * `BENCH_SMOKE=1`  — shrink problem sizes/measurement windows so the
//!   whole bench suite finishes in CI-smoke time. Relative comparisons
//!   (fast vs scalar, pack vs cursor) stay meaningful; absolute numbers
//!   are noisy and must not be quoted.
//! * `BENCH_JSON=path` — merge this bench's section into the JSON
//!   document at `path` (created when absent, other sections preserved),
//!   so quantize → encode → exchange can each run as a separate binary
//!   and still produce one `BENCH_hotloop.json`.
//!
//! Each bench includes this file as a private module, so per-binary
//! dead-code warnings on unused helpers are expected and allowed.
#![allow(dead_code)]

use aqsgd::util::json::Json;
use std::time::Instant;

/// Schema tag for the merged hot-loop perf artifact. Bump on any
/// incompatible key change; ci.sh validates it.
pub const BENCH_SCHEMA: &str = "aqsgd-bench-hotloop/v1";

/// Run `f` repeatedly for ~`target_ms` and return seconds per call.
pub fn time_per_call<F: FnMut()>(mut f: F, target_ms: u64) -> f64 {
    // Warmup.
    f();
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let reps = ((target_ms as f64 / 1e3 / once).ceil() as usize).clamp(3, 10_000);
    let mut samples = Vec::with_capacity(5);
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..reps {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / reps as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

pub fn report(name: &str, secs_per_call: f64, items: usize) {
    println!(
        "{name:<44} {:>10.3} µs/call {:>9.2} ns/item {:>10.1} Mitem/s",
        secs_per_call * 1e6,
        secs_per_call * 1e9 / items as f64,
        items as f64 / secs_per_call / 1e6
    );
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// True when `BENCH_SMOKE=1`: benches shrink sizes and timing windows.
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// `full` normally, `small` under `BENCH_SMOKE=1`.
pub fn sized(full: usize, small: usize) -> usize {
    if smoke() {
        small
    } else {
        full
    }
}

/// Measurement window in ms: `full` normally, 20 ms under smoke.
pub fn window_ms(full: u64) -> u64 {
    if smoke() {
        20
    } else {
        full
    }
}

/// Merge `section` into the JSON document named by `BENCH_JSON` and
/// rewrite it (no-op when the variable is unset). The document root is
/// an object carrying `schema`, `meta`, and one sub-object per bench
/// binary; an existing file is parsed first so sections written by the
/// other binaries survive, and an unparseable or wrong-schema file is
/// restarted from empty rather than trusted.
pub fn emit_section(name: &str, section: Json) {
    let Some(path) = std::env::var_os("BENCH_JSON") else {
        return;
    };
    let mut doc = std::fs::read_to_string(&path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .filter(|j| j.get("schema").and_then(Json::as_str) == Some(BENCH_SCHEMA))
        .unwrap_or_else(Json::obj);
    doc.insert("schema", Json::Str(BENCH_SCHEMA.into()));
    let mut meta = Json::obj();
    meta.insert("smoke", Json::Bool(smoke()));
    meta.insert(
        "threads",
        Json::Num(std::thread::available_parallelism().map_or(1, |n| n.get()) as f64),
    );
    doc.insert("meta", meta);
    doc.insert(name, section);
    let text = format!("{doc}\n");
    if let Err(e) = std::fs::write(&path, text) {
        eprintln!("[bench] cannot write {path:?}: {e}");
        std::process::exit(1);
    }
    println!("\n[bench] wrote section {name:?} to {path:?}");
}

/// Write a standalone single-binary perf document (the pipeline
/// trajectory, say) to the path named by `env_var`, tagged with
/// `schema` plus the same `meta` block the merged document carries.
/// No-op when the variable is unset.
pub fn emit_doc(env_var: &str, schema: &str, mut doc: Json) {
    let Some(path) = std::env::var_os(env_var) else {
        return;
    };
    doc.insert("schema", Json::Str(schema.into()));
    let mut meta = Json::obj();
    meta.insert("smoke", Json::Bool(smoke()));
    meta.insert(
        "threads",
        Json::Num(std::thread::available_parallelism().map_or(1, |n| n.get()) as f64),
    );
    doc.insert("meta", meta);
    let text = format!("{doc}\n");
    if let Err(e) = std::fs::write(&path, text) {
        eprintln!("[bench] cannot write {path:?}: {e}");
        std::process::exit(1);
    }
    println!("\n[bench] wrote {schema} document to {path:?}");
}

/// Load the `BENCH_JSON` document, if the variable is set and the file
/// parses. Used by the last bench in the ci.sh chain to validate that
/// every section landed.
pub fn load_doc() -> Option<Json> {
    let path = std::env::var_os("BENCH_JSON")?;
    let text = std::fs::read_to_string(&path).ok()?;
    Json::parse(&text).ok()
}

/// One measured throughput row: `{"secs_per_call": s, "items": n,
/// "items_per_sec": n/s}` plus any extra keys the caller tacks on.
pub fn throughput_row(secs_per_call: f64, items: usize) -> Json {
    let mut row = Json::obj();
    row.insert("secs_per_call", Json::Num(secs_per_call));
    row.insert("items", Json::Num(items as f64));
    row.insert("items_per_sec", Json::Num(items as f64 / secs_per_call));
    row
}
